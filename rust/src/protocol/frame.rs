//! Protocol v1: length-prefixed binary frames (DESIGN.md §15).
//!
//! Every frame is `[FRAME_MAGIC][type: u8][len: u32 LE][payload: len
//! bytes]`. [`FRAME_MAGIC`] is `0xF1` — not printable ASCII, so no v0
//! command line can start with it; the server sniffs the first byte of
//! a connection and that is the entire codec negotiation. Inside a
//! payload:
//!
//!   * integers are little-endian (`u32`/`u64`; `i8` as one byte),
//!   * `f64` travels as its IEEE-754 bit pattern (`u64` LE) — exact,
//!   * a string is `u32` byte length + UTF-8 bytes,
//!   * an optional tenant is a string where empty = `None` (tenant
//!     names are validated non-empty at registration),
//!   * a feature vector is `u32` count + that many `f64`s,
//!   * a row/prediction list is `u32` count + the elements.
//!
//! Decoders consume the whole payload and reject trailing bytes, so
//! `decode(encode(x)) == x` is exact for every frame type — the
//! property tests in tests/proptests.rs hold the codec to that. A
//! malformed payload is reported per-frame ([`Decoded::Malformed`])
//! without desynchronising the stream: the transport already consumed
//! exactly `len` bytes.

use std::io::{BufRead, Read, Write};

use super::stats::{
    DieOccupancy, GovernorStats, Segment, StageStats, StatsSnapshot, TenantStats, TimelineEvent,
    TraceEntry, TraceOutcome, SEGMENTS,
};
use super::{Codec, Decoded, PredictRow, Prediction, Request, Response};

/// First byte of every v1 frame; the codec-negotiation sniff byte.
pub const FRAME_MAGIC: u8 = 0xF1;

/// Upper bound on one frame's payload — a corrupted or hostile length
/// prefix must not allocate unbounded memory.
pub const MAX_FRAME_LEN: u32 = 64 * 1024 * 1024;

// Request frame types.
const T_PING: u8 = 0x01;
const T_STATS: u8 = 0x02;
const T_HEALTH: u8 = 0x03;
const T_MODELS: u8 = 0x04;
const T_DRAIN: u8 = 0x05;
const T_PREDICT: u8 = 0x06;
const T_BATCH: u8 = 0x07;
const T_REGISTER: u8 = 0x08;
const T_UNREGISTER: u8 = 0x09;
const T_QUIT: u8 = 0x0A;
const T_TRACE: u8 = 0x0B;
const T_SNAPSHOT: u8 = 0x0C;
const T_GOVERNOR: u8 = 0x0D;
const T_TIMELINE: u8 = 0x0E;
/// `Hello{token}` handshake binding a connection to a tenant scope
/// (DESIGN.md §20). Sent bare — never inside a correlation envelope.
pub const T_HELLO: u8 = 0x0F;
/// One labelled OS-ELM row streamed into a tenant's heads.
pub const T_TENANT_UPDATE: u8 = 0x10;
/// `BatchPredict` asking for streamed per-row replies.
pub const T_BATCH_STREAM: u8 = 0x11;
/// Correlation envelope: `u64` id + one inner request frame, so a
/// connection can carry many in-flight requests at once.
pub const T_CORR: u8 = 0x12;

// Response frame types (high bit set).
const R_PONG: u8 = 0x81;
const R_STATS: u8 = 0x82;
const R_HEALTH: u8 = 0x83;
const R_MODELS: u8 = 0x84;
const R_DRAINING: u8 = 0x85;
const R_PREDICT: u8 = 0x86;
const R_BATCH: u8 = 0x87;
const R_REGISTERED: u8 = 0x88;
const R_UNREGISTERED: u8 = 0x89;
const R_TRACE: u8 = 0x8A;
const R_SNAPSHOT: u8 = 0x8B;
const R_GOVERNOR: u8 = 0x8C;
const R_TIMELINE: u8 = 0x8D;
/// Hello accepted: the granted tenant scope (`*` = unrestricted).
pub const R_HELLO: u8 = 0x8E;
/// TenantUpdate applied on every die.
pub const R_UPDATED: u8 = 0x8F;
/// Correlation envelope: `u64` id + one inner response frame.
pub const R_CORR: u8 = 0x90;
/// One streamed BatchPredict row: corr id + row index + prediction.
pub const R_STREAM_ROW: u8 = 0x91;
/// End of a streamed BatchPredict: corr id + row count + passes.
pub const R_STREAM_END: u8 = 0x92;
const R_ERROR: u8 = 0xFF;

// --- payload writers ---

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    put_u64(buf, v.to_bits());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn put_tenant(buf: &mut Vec<u8>, tenant: Option<&str>) {
    put_str(buf, tenant.unwrap_or(""));
}

fn put_features(buf: &mut Vec<u8>, features: &[f64]) {
    put_u32(buf, features.len() as u32);
    for &v in features {
        put_f64(buf, v);
    }
}

fn put_prediction(buf: &mut Vec<u8>, p: &Prediction) {
    buf.push(p.label as u8);
    put_f64(buf, p.score);
    put_tenant(buf, p.tenant.as_deref());
}

fn put_trace_entry(buf: &mut Vec<u8>, t: &TraceEntry) {
    put_u64(buf, t.id);
    put_tenant(buf, t.tenant.as_deref());
    put_u32(buf, t.die);
    buf.push(t.pjrt as u8);
    put_u32(buf, t.passes);
    put_u64(buf, t.queue_us);
    put_u64(buf, t.batch_us);
    put_u64(buf, t.compute_us);
    put_u64(buf, t.total_us);
    buf.push(t.outcome.code());
}

fn put_timeline_event(buf: &mut Vec<u8>, e: &TimelineEvent) {
    put_u32(buf, e.die);
    buf.push(e.seg.code());
    put_u64(buf, e.start_us);
    put_u64(buf, e.end_us);
    buf.push(e.req_id.is_some() as u8);
    put_u64(buf, e.req_id.unwrap_or(0));
}

fn put_stage(buf: &mut Vec<u8>, s: &StageStats) {
    put_u64(buf, s.count);
    put_u64(buf, s.sum_us);
    put_u64(buf, s.p50_us);
    put_u64(buf, s.p90_us);
    put_u64(buf, s.p99_us);
}

fn put_snapshot(buf: &mut Vec<u8>, s: &StatsSnapshot) {
    put_u32(buf, s.version);
    put_u64(buf, s.uptime_us);
    put_u64(buf, s.requests);
    put_u64(buf, s.submissions);
    put_u64(buf, s.responses);
    put_u64(buf, s.batches);
    put_u64(buf, s.pjrt_batches);
    put_u64(buf, s.sim_batches);
    put_u64(buf, s.batched_requests);
    put_u64(buf, s.conversions);
    put_u64(buf, s.probes);
    put_u64(buf, s.renorms);
    put_u64(buf, s.refits);
    put_u64(buf, s.quarantines);
    put_u64(buf, s.promotions);
    put_u64(buf, s.energy_fj);
    put_u64(buf, s.macs);
    put_stage(buf, &s.latency);
    put_stage(buf, &s.queue);
    put_stage(buf, &s.batch_wait);
    put_stage(buf, &s.compute);
    put_u64(buf, s.governor.ticks);
    put_u64(buf, s.governor.raises);
    put_u64(buf, s.governor.lowers);
    put_u64(buf, s.governor.rejected);
    put_u64(buf, s.governor.fj_saved);
    put_u32(buf, s.governor.points.len() as u32);
    for &b in &s.governor.points {
        put_u32(buf, b);
    }
    put_u32(buf, s.tenants.len() as u32);
    for t in &s.tenants {
        put_str(buf, &t.name);
        put_u64(buf, t.requests);
        put_u64(buf, t.responses);
        put_u64(buf, t.energy_fj);
        put_u64(buf, t.busy_us);
        put_f64(buf, t.train_score);
        put_stage(buf, &t.latency);
    }
    // v3 fields ride after the tenant block so earlier fixed offsets
    // (the hostile-count tests pin them) stay put
    put_u32(buf, s.occupancy.len() as u32);
    for o in &s.occupancy {
        put_u32(buf, o.die);
        for &us in &o.seg_us {
            put_u64(buf, us);
        }
    }
    put_u64(buf, s.slo_breaches);
}

// --- payload reader ---

/// Bounds-checked cursor over one frame's payload.
struct Cur<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Self {
        Cur { b, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| format!("frame truncated at byte {}", self.pos))?;
        let out = &self.b[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn str(&mut self) -> Result<String, String> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|e| format!("bad utf-8 in frame: {e}"))
    }

    fn tenant(&mut self) -> Result<Option<String>, String> {
        let s = self.str()?;
        Ok(if s.is_empty() { None } else { Some(s) })
    }

    fn features(&mut self) -> Result<Vec<f64>, String> {
        let n = self.u32()? as usize;
        // 8 bytes per f64 must still fit in the remaining payload
        if n > (self.b.len() - self.pos) / 8 {
            return Err(format!("feature count {n} exceeds the frame"));
        }
        (0..n).map(|_| self.f64()).collect()
    }

    /// Payload bytes not yet consumed — the bound for hostile
    /// list-count checks.
    fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    /// Decoders must consume the payload exactly.
    fn done(&self) -> Result<(), String> {
        if self.pos == self.b.len() {
            Ok(())
        } else {
            Err(format!(
                "{} trailing bytes after the payload",
                self.b.len() - self.pos
            ))
        }
    }
}

// --- frame-level encode/decode ---

/// Encode a request as (frame type, payload).
pub fn encode_request(req: &Request) -> (u8, Vec<u8>) {
    let mut buf = Vec::new();
    let ty = match req {
        Request::Ping => T_PING,
        Request::Stats => T_STATS,
        Request::Health => T_HEALTH,
        Request::Models => T_MODELS,
        Request::Drain { die } => {
            put_u32(&mut buf, *die as u32);
            T_DRAIN
        }
        Request::Predict { tenant, features } => {
            put_tenant(&mut buf, tenant.as_deref());
            put_features(&mut buf, features);
            T_PREDICT
        }
        Request::BatchPredict { rows } => {
            put_u32(&mut buf, rows.len() as u32);
            for row in rows {
                put_tenant(&mut buf, row.tenant.as_deref());
                put_features(&mut buf, &row.features);
            }
            T_BATCH
        }
        Request::Register { name, dataset, seed } => {
            put_str(&mut buf, name);
            put_str(&mut buf, dataset);
            put_u64(&mut buf, *seed);
            T_REGISTER
        }
        Request::Unregister { name } => {
            put_str(&mut buf, name);
            T_UNREGISTER
        }
        Request::Trace { last } => {
            put_u32(&mut buf, *last as u32);
            T_TRACE
        }
        Request::Snapshot => T_SNAPSHOT,
        Request::Governor => T_GOVERNOR,
        Request::Timeline { last } => {
            put_u32(&mut buf, *last as u32);
            T_TIMELINE
        }
        Request::Hello { token } => {
            put_str(&mut buf, token);
            T_HELLO
        }
        Request::TenantUpdate { name, features, targets } => {
            put_str(&mut buf, name);
            put_features(&mut buf, features);
            put_features(&mut buf, targets);
            T_TENANT_UPDATE
        }
        Request::BatchStream { rows } => {
            put_u32(&mut buf, rows.len() as u32);
            for row in rows {
                put_tenant(&mut buf, row.tenant.as_deref());
                put_features(&mut buf, &row.features);
            }
            T_BATCH_STREAM
        }
    };
    (ty, buf)
}

/// Decode a request frame. `Ok(None)` is the quit frame.
pub fn decode_request(ty: u8, payload: &[u8]) -> Result<Option<Request>, String> {
    let mut c = Cur::new(payload);
    let req = match ty {
        T_PING => Request::Ping,
        T_STATS => Request::Stats,
        T_HEALTH => Request::Health,
        T_MODELS => Request::Models,
        T_QUIT => {
            c.done()?;
            return Ok(None);
        }
        T_DRAIN => Request::Drain { die: c.u32()? as usize },
        T_PREDICT => Request::Predict { tenant: c.tenant()?, features: c.features()? },
        T_BATCH => {
            let n = c.u32()? as usize;
            let mut rows = Vec::new();
            for _ in 0..n {
                rows.push(PredictRow { tenant: c.tenant()?, features: c.features()? });
            }
            Request::BatchPredict { rows }
        }
        T_REGISTER => Request::Register {
            name: c.str()?,
            dataset: c.str()?,
            seed: c.u64()?,
        },
        T_UNREGISTER => Request::Unregister { name: c.str()? },
        T_TRACE => Request::Trace { last: c.u32()? as usize },
        T_SNAPSHOT => Request::Snapshot,
        T_GOVERNOR => Request::Governor,
        T_TIMELINE => Request::Timeline { last: c.u32()? as usize },
        T_HELLO => Request::Hello { token: c.str()? },
        T_TENANT_UPDATE => Request::TenantUpdate {
            name: c.str()?,
            features: c.features()?,
            targets: c.features()?,
        },
        T_BATCH_STREAM => {
            let n = c.u32()? as usize;
            let mut rows = Vec::new();
            for _ in 0..n {
                rows.push(PredictRow { tenant: c.tenant()?, features: c.features()? });
            }
            Request::BatchStream { rows }
        }
        T_CORR => {
            return Err(
                "correlation envelopes are transport frames; \
                 decode via decode_correlated_request"
                    .into(),
            )
        }
        other => return Err(format!("unknown request frame type {other:#04x}")),
    };
    c.done()?;
    Ok(Some(req))
}

/// Encode a response as (frame type, payload).
pub fn encode_response(resp: &Response) -> (u8, Vec<u8>) {
    let mut buf = Vec::new();
    let ty = match resp {
        Response::Pong => R_PONG,
        Response::Stats(s) => {
            put_str(&mut buf, s);
            R_STATS
        }
        Response::Health(s) => {
            put_str(&mut buf, s);
            R_HEALTH
        }
        Response::Models(s) => {
            put_str(&mut buf, s);
            R_MODELS
        }
        Response::Draining { die } => {
            put_u32(&mut buf, *die as u32);
            R_DRAINING
        }
        Response::Predict(p) => {
            put_prediction(&mut buf, p);
            R_PREDICT
        }
        Response::Batch(ps) => {
            put_u32(&mut buf, ps.len() as u32);
            for p in ps {
                put_prediction(&mut buf, p);
            }
            R_BATCH
        }
        Response::Registered { name, task, score } => {
            put_str(&mut buf, name);
            put_str(&mut buf, task);
            put_f64(&mut buf, *score);
            R_REGISTERED
        }
        Response::Unregistered { name } => {
            put_str(&mut buf, name);
            R_UNREGISTERED
        }
        Response::Trace(ts) => {
            put_u32(&mut buf, ts.len() as u32);
            for t in ts {
                put_trace_entry(&mut buf, t);
            }
            R_TRACE
        }
        Response::Snapshot(s) => {
            put_snapshot(&mut buf, s);
            R_SNAPSHOT
        }
        Response::Governor(s) => {
            put_str(&mut buf, s);
            R_GOVERNOR
        }
        Response::Timeline(es) => {
            put_u32(&mut buf, es.len() as u32);
            for e in es {
                put_timeline_event(&mut buf, e);
            }
            R_TIMELINE
        }
        Response::Error(e) => {
            put_str(&mut buf, e);
            R_ERROR
        }
        Response::HelloOk { tenants } => {
            put_u32(&mut buf, tenants.len() as u32);
            for t in tenants {
                put_str(&mut buf, t);
            }
            R_HELLO
        }
        Response::Updated { name } => {
            put_str(&mut buf, name);
            R_UPDATED
        }
    };
    (ty, buf)
}

fn prediction(c: &mut Cur<'_>) -> Result<Prediction, String> {
    Ok(Prediction {
        label: c.u8()? as i8,
        score: c.f64()?,
        tenant: c.tenant()?,
    })
}

fn trace_entry(c: &mut Cur<'_>) -> Result<TraceEntry, String> {
    Ok(TraceEntry {
        id: c.u64()?,
        tenant: c.tenant()?,
        die: c.u32()?,
        pjrt: c.u8()? != 0,
        passes: c.u32()?,
        queue_us: c.u64()?,
        batch_us: c.u64()?,
        compute_us: c.u64()?,
        total_us: c.u64()?,
        outcome: {
            let code = c.u8()?;
            TraceOutcome::from_code(code)
                .ok_or_else(|| format!("unknown trace outcome code {code}"))?
        },
    })
}

fn stage(c: &mut Cur<'_>) -> Result<StageStats, String> {
    Ok(StageStats {
        count: c.u64()?,
        sum_us: c.u64()?,
        p50_us: c.u64()?,
        p90_us: c.u64()?,
        p99_us: c.u64()?,
    })
}

// Smallest possible wire sizes, the bound for hostile-count checks:
// a trace entry is 8+4+4+1+4+4*8+1 bytes, a tenant stats block is
// 4+4*8+8+5*8 bytes (empty names), a timeline event is 4+1+8+8+1+8
// bytes, a die occupancy block is 4+7*8 bytes.
const MIN_TRACE_ENTRY_LEN: usize = 54;
const MIN_TENANT_STATS_LEN: usize = 84;
const MIN_TIMELINE_EVENT_LEN: usize = 30;
const MIN_DIE_OCCUPANCY_LEN: usize = 60;

fn timeline_event(c: &mut Cur<'_>) -> Result<TimelineEvent, String> {
    Ok(TimelineEvent {
        die: c.u32()?,
        seg: {
            let code = c.u8()?;
            Segment::from_code(code)
                .ok_or_else(|| format!("unknown timeline segment code {code}"))?
        },
        start_us: c.u64()?,
        end_us: c.u64()?,
        req_id: {
            let has = c.u8()? != 0;
            let id = c.u64()?;
            has.then_some(id)
        },
    })
}

fn snapshot(c: &mut Cur<'_>) -> Result<StatsSnapshot, String> {
    let version = c.u32()?;
    if version != super::stats::SNAPSHOT_VERSION {
        return Err(format!("unsupported snapshot version {version}"));
    }
    let mut s = StatsSnapshot {
        version,
        uptime_us: c.u64()?,
        requests: c.u64()?,
        submissions: c.u64()?,
        responses: c.u64()?,
        batches: c.u64()?,
        pjrt_batches: c.u64()?,
        sim_batches: c.u64()?,
        batched_requests: c.u64()?,
        conversions: c.u64()?,
        probes: c.u64()?,
        renorms: c.u64()?,
        refits: c.u64()?,
        quarantines: c.u64()?,
        promotions: c.u64()?,
        energy_fj: c.u64()?,
        macs: c.u64()?,
        latency: stage(c)?,
        queue: stage(c)?,
        batch_wait: stage(c)?,
        compute: stage(c)?,
        governor: GovernorStats {
            ticks: c.u64()?,
            raises: c.u64()?,
            lowers: c.u64()?,
            rejected: c.u64()?,
            fj_saved: c.u64()?,
            points: Vec::new(),
        },
        tenants: Vec::new(),
        occupancy: Vec::new(),
        slo_breaches: 0,
    };
    let np = c.u32()? as usize;
    if np > c.remaining() / 4 {
        return Err(format!("governor point count {np} exceeds the frame"));
    }
    for _ in 0..np {
        s.governor.points.push(c.u32()?);
    }
    let n = c.u32()? as usize;
    if n > c.remaining() / MIN_TENANT_STATS_LEN {
        return Err(format!("tenant count {n} exceeds the frame"));
    }
    for _ in 0..n {
        s.tenants.push(TenantStats {
            name: c.str()?,
            requests: c.u64()?,
            responses: c.u64()?,
            energy_fj: c.u64()?,
            busy_us: c.u64()?,
            train_score: c.f64()?,
            latency: stage(c)?,
        });
    }
    let no = c.u32()? as usize;
    if no > c.remaining() / MIN_DIE_OCCUPANCY_LEN {
        return Err(format!("occupancy count {no} exceeds the frame"));
    }
    for _ in 0..no {
        let die = c.u32()?;
        let mut seg_us = [0u64; SEGMENTS];
        for us in &mut seg_us {
            *us = c.u64()?;
        }
        s.occupancy.push(DieOccupancy { die, seg_us });
    }
    s.slo_breaches = c.u64()?;
    Ok(s)
}

/// Decode a response frame.
pub fn decode_response(ty: u8, payload: &[u8]) -> Result<Response, String> {
    let mut c = Cur::new(payload);
    let resp = match ty {
        R_PONG => Response::Pong,
        R_STATS => Response::Stats(c.str()?),
        R_HEALTH => Response::Health(c.str()?),
        R_MODELS => Response::Models(c.str()?),
        R_DRAINING => Response::Draining { die: c.u32()? as usize },
        R_PREDICT => Response::Predict(prediction(&mut c)?),
        R_BATCH => {
            let n = c.u32()? as usize;
            let mut ps = Vec::new();
            for _ in 0..n {
                ps.push(prediction(&mut c)?);
            }
            Response::Batch(ps)
        }
        R_REGISTERED => Response::Registered {
            name: c.str()?,
            task: c.str()?,
            score: c.f64()?,
        },
        R_UNREGISTERED => Response::Unregistered { name: c.str()? },
        R_TRACE => {
            let n = c.u32()? as usize;
            if n > c.remaining() / MIN_TRACE_ENTRY_LEN {
                return Err(format!("trace count {n} exceeds the frame"));
            }
            let mut ts = Vec::new();
            for _ in 0..n {
                ts.push(trace_entry(&mut c)?);
            }
            Response::Trace(ts)
        }
        R_SNAPSHOT => Response::Snapshot(snapshot(&mut c)?),
        R_GOVERNOR => Response::Governor(c.str()?),
        R_TIMELINE => {
            let n = c.u32()? as usize;
            if n > c.remaining() / MIN_TIMELINE_EVENT_LEN {
                return Err(format!("timeline count {n} exceeds the frame"));
            }
            let mut es = Vec::new();
            for _ in 0..n {
                es.push(timeline_event(&mut c)?);
            }
            Response::Timeline(es)
        }
        R_ERROR => Response::Error(c.str()?),
        R_HELLO => {
            // an empty string is 4 bytes, the hostile-count bound
            let n = c.u32()? as usize;
            if n > c.remaining() / 4 {
                return Err(format!("tenant scope count {n} exceeds the frame"));
            }
            let mut tenants = Vec::new();
            for _ in 0..n {
                tenants.push(c.str()?);
            }
            Response::HelloOk { tenants }
        }
        R_UPDATED => Response::Updated { name: c.str()? },
        R_CORR => {
            return Err(
                "correlation envelopes are transport frames; \
                 decode via decode_correlated_response"
                    .into(),
            )
        }
        R_STREAM_ROW | R_STREAM_END => {
            return Err(
                "stream frames are transport frames; decode via \
                 decode_stream_row / decode_stream_end"
                    .into(),
            )
        }
        other => return Err(format!("unknown response frame type {other:#04x}")),
    };
    c.done()?;
    Ok(resp)
}

// --- correlation envelopes and stream frames (DESIGN.md §20) ---

/// Encode a correlated request: `[corr: u64][inner type: u8][inner
/// payload]` under [`T_CORR`]. The reactor echoes `corr` on the
/// matching [`R_CORR`] (or stream) frames, so responses arriving in
/// completion order can be matched back to their requests.
pub fn encode_correlated_request(corr: u64, req: &Request) -> (u8, Vec<u8>) {
    let (ity, ipayload) = encode_request(req);
    let mut buf = Vec::with_capacity(9 + ipayload.len());
    put_u64(&mut buf, corr);
    buf.push(ity);
    buf.extend_from_slice(&ipayload);
    (T_CORR, buf)
}

/// Decode a correlated request envelope. Nested envelopes, handshakes
/// and quits may not ride inside one: a correlation id spans exactly
/// one dispatchable request.
pub fn decode_correlated_request(payload: &[u8]) -> Result<(u64, Request), String> {
    let mut c = Cur::new(payload);
    let corr = c.u64()?;
    let ity = c.u8()?;
    match ity {
        T_CORR => return Err("nested correlation envelopes are not allowed".into()),
        T_HELLO => return Err("Hello may not ride a correlation envelope".into()),
        T_QUIT => return Err("Quit may not ride a correlation envelope".into()),
        _ => {}
    }
    let inner = c.take(c.remaining())?;
    match decode_request(ity, inner)? {
        Some(req) => Ok((corr, req)),
        None => Err("Quit may not ride a correlation envelope".into()),
    }
}

/// Encode a correlated response envelope under [`R_CORR`].
pub fn encode_correlated_response(corr: u64, resp: &Response) -> (u8, Vec<u8>) {
    let (ity, ipayload) = encode_response(resp);
    let mut buf = Vec::with_capacity(9 + ipayload.len());
    put_u64(&mut buf, corr);
    buf.push(ity);
    buf.extend_from_slice(&ipayload);
    (R_CORR, buf)
}

/// Decode a correlated response envelope.
pub fn decode_correlated_response(payload: &[u8]) -> Result<(u64, Response), String> {
    let mut c = Cur::new(payload);
    let corr = c.u64()?;
    let ity = c.u8()?;
    if ity == R_CORR {
        return Err("nested correlation envelopes are not allowed".into());
    }
    let inner = c.take(c.remaining())?;
    Ok((corr, decode_response(ity, inner)?))
}

/// Encode one streamed BatchPredict row: `[corr][row index][prediction]`
/// under [`R_STREAM_ROW`]. Rows are emitted in completion order; the
/// index places each back in its submitted position.
pub fn encode_stream_row(corr: u64, index: u32, p: &Prediction) -> (u8, Vec<u8>) {
    let mut buf = Vec::new();
    put_u64(&mut buf, corr);
    put_u32(&mut buf, index);
    put_prediction(&mut buf, p);
    (R_STREAM_ROW, buf)
}

/// Decode one streamed BatchPredict row.
pub fn decode_stream_row(payload: &[u8]) -> Result<(u64, u32, Prediction), String> {
    let mut c = Cur::new(payload);
    let corr = c.u64()?;
    let index = c.u32()?;
    let p = prediction(&mut c)?;
    c.done()?;
    Ok((corr, index, p))
}

/// Encode the end-of-stream frame: `[corr][row count][total passes]`
/// under [`R_STREAM_END`].
pub fn encode_stream_end(corr: u64, rows: u32, passes: u64) -> (u8, Vec<u8>) {
    let mut buf = Vec::new();
    put_u64(&mut buf, corr);
    put_u32(&mut buf, rows);
    put_u64(&mut buf, passes);
    (R_STREAM_END, buf)
}

/// Decode the end-of-stream frame into (corr, row count, passes).
pub fn decode_stream_end(payload: &[u8]) -> Result<(u64, u32, u64), String> {
    let mut c = Cur::new(payload);
    let corr = c.u64()?;
    let rows = c.u32()?;
    let passes = c.u64()?;
    c.done()?;
    Ok((corr, rows, passes))
}

// --- transport ---

/// Incremental frame parser over a byte buffer (the reactor's
/// nonblocking read path): `Ok(Some((type, payload, consumed)))` when
/// a whole frame is buffered, `Ok(None)` when more bytes are needed.
/// Bad magic and an oversized length prefix are hard errors — the
/// stream cannot be resynchronised. Feeding a buffer one byte at a
/// time yields exactly the frames [`read_frame`] would.
pub fn take_frame(buf: &[u8]) -> std::io::Result<Option<(u8, Vec<u8>, usize)>> {
    let Some(&first) = buf.first() else {
        return Ok(None);
    };
    if first != FRAME_MAGIC {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("bad frame magic {first:#04x}"),
        ));
    }
    if buf.len() < 6 {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[2..6].try_into().unwrap());
    if len > MAX_FRAME_LEN {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds the {MAX_FRAME_LEN} byte cap"),
        ));
    }
    let total = 6 + len as usize;
    if buf.len() < total {
        return Ok(None);
    }
    Ok(Some((buf[1], buf[6..total].to_vec(), total)))
}

/// Render one frame to owned bytes — the reactor's write-buffer path
/// (its nonblocking sockets never see a blocking `Write` call).
pub fn frame_bytes(ty: u8, payload: &[u8]) -> std::io::Result<Vec<u8>> {
    let mut buf = Vec::with_capacity(6 + payload.len());
    write_frame(&mut buf, ty, payload)?;
    Ok(buf)
}

/// Write one `[magic][type][len][payload]` frame and flush.
pub fn write_frame(w: &mut dyn Write, ty: u8, payload: &[u8]) -> std::io::Result<()> {
    // enforce the cap on encode too: a huge batch must fail fast here
    // with a cause, not as a silent `as u32` wrap (a corrupted length
    // prefix desyncs the peer) or an opaque hangup from the reader side
    if payload.len() > MAX_FRAME_LEN as usize {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            format!(
                "frame of {} bytes exceeds the {MAX_FRAME_LEN} byte cap \
                 (split the batch into smaller chunks)",
                payload.len()
            ),
        ));
    }
    let mut head = [0u8; 6];
    head[0] = FRAME_MAGIC;
    head[1] = ty;
    head[2..6].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&head)?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one frame. `Ok(None)` = clean EOF before a new frame; a
/// truncated header/payload, a bad magic byte or an oversized length
/// prefix are hard errors (the stream cannot be resynchronised).
pub fn read_frame(r: &mut dyn BufRead) -> std::io::Result<Option<(u8, Vec<u8>)>> {
    let mut head = [0u8; 6];
    // distinguish clean EOF (no first byte) from a truncated header
    let n = r.read(&mut head[..1])?;
    if n == 0 {
        return Ok(None);
    }
    r.read_exact(&mut head[1..])?;
    if head[0] != FRAME_MAGIC {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("bad frame magic {:#04x}", head[0]),
        ));
    }
    let len = u32::from_le_bytes(head[2..6].try_into().unwrap());
    if len > MAX_FRAME_LEN {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds the {MAX_FRAME_LEN} byte cap"),
        ));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    Ok(Some((head[1], payload)))
}

/// The v1 framed codec. Stateless: one value serves a whole connection.
#[derive(Clone, Copy, Debug, Default)]
pub struct FrameCodec;

impl Codec for FrameCodec {
    fn version(&self) -> u8 {
        1
    }

    fn read_request(&mut self, r: &mut dyn BufRead) -> std::io::Result<Decoded> {
        let Some((ty, payload)) = read_frame(r)? else {
            return Ok(Decoded::Eof);
        };
        Ok(match decode_request(ty, &payload) {
            Ok(None) => Decoded::Quit,
            Ok(Some(req)) => Decoded::Request(req),
            Err(e) => Decoded::Malformed(e),
        })
    }

    fn write_response(&mut self, w: &mut dyn Write, resp: &Response) -> std::io::Result<()> {
        let (ty, payload) = encode_response(resp);
        write_frame(w, ty, &payload)
    }

    fn write_request(&mut self, w: &mut dyn Write, req: &Request) -> std::io::Result<()> {
        let (ty, payload) = encode_request(req);
        write_frame(w, ty, &payload)
    }

    fn read_response(
        &mut self,
        r: &mut dyn BufRead,
        _expect: &Request,
    ) -> std::io::Result<Option<Response>> {
        let Some((ty, payload)) = read_frame(r)? else {
            return Ok(None);
        };
        decode_response(ty, &payload)
            .map(Some)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
    }

    fn write_quit(&mut self, w: &mut dyn Write) -> std::io::Result<()> {
        write_frame(w, T_QUIT, &[])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_frames_roundtrip_via_io() {
        let mut codec = FrameCodec;
        let req = Request::BatchPredict {
            rows: vec![
                PredictRow { tenant: None, features: vec![0.5, -0.25] },
                PredictRow { tenant: Some("bright".into()), features: vec![] },
            ],
        };
        let mut buf = Vec::new();
        codec.write_request(&mut buf, &req).unwrap();
        assert_eq!(buf[0], FRAME_MAGIC);
        let mut r: &[u8] = &buf;
        match codec.read_request(&mut r).unwrap() {
            Decoded::Request(back) => assert_eq!(back, req),
            other => panic!("{other:?}"),
        }
        assert!(matches!(codec.read_request(&mut r).unwrap(), Decoded::Eof));
    }

    #[test]
    fn response_frames_roundtrip_via_io() {
        let mut codec = FrameCodec;
        let resp = Response::Batch(vec![
            Prediction { label: -1, score: 0.125, tenant: None },
            Prediction { label: 7, score: -3.5, tenant: Some("digits".into()) },
        ]);
        let mut buf = Vec::new();
        codec.write_response(&mut buf, &resp).unwrap();
        let mut r: &[u8] = &buf;
        let expect = Request::BatchPredict { rows: vec![] };
        assert_eq!(codec.read_response(&mut r, &expect).unwrap(), Some(resp));
        assert_eq!(codec.read_response(&mut r, &expect).unwrap(), None);
    }

    #[test]
    fn quit_frame_and_eof_are_distinct() {
        let mut codec = FrameCodec;
        let mut buf = Vec::new();
        codec.write_quit(&mut buf).unwrap();
        let mut r: &[u8] = &buf;
        assert!(matches!(codec.read_request(&mut r).unwrap(), Decoded::Quit));
        let mut empty: &[u8] = &[];
        assert!(matches!(codec.read_request(&mut empty).unwrap(), Decoded::Eof));
    }

    #[test]
    fn malformed_payload_is_recoverable_and_keeps_sync() {
        // an in-range frame with a garbage payload answers Malformed and
        // the NEXT frame still parses — the stream never desyncs
        let mut codec = FrameCodec;
        let mut buf = Vec::new();
        write_frame(&mut buf, T_DRAIN, &[1, 2]).unwrap(); // too short for u32
        codec.write_request(&mut buf, &Request::Ping).unwrap();
        let mut r: &[u8] = &buf;
        assert!(matches!(codec.read_request(&mut r).unwrap(), Decoded::Malformed(_)));
        assert!(matches!(
            codec.read_request(&mut r).unwrap(),
            Decoded::Request(Request::Ping)
        ));
    }

    #[test]
    fn bad_magic_and_oversized_frames_are_hard_errors() {
        let mut codec = FrameCodec;
        let mut r: &[u8] = b"CLASSIFY 1,2\n"; // v0 bytes into the v1 codec
        assert!(codec.read_request(&mut r).is_err());
        let mut head = vec![FRAME_MAGIC, T_PING];
        head.extend_from_slice(&(MAX_FRAME_LEN + 1).to_le_bytes());
        let mut r: &[u8] = &head;
        assert!(codec.read_request(&mut r).is_err());
    }

    #[test]
    fn decoders_reject_trailing_bytes() {
        let (ty, mut payload) = encode_request(&Request::Ping);
        payload.push(0);
        assert!(decode_request(ty, &payload).is_err());
        let (ty, mut payload) = encode_response(&Response::Pong);
        payload.push(0);
        assert!(decode_response(ty, &payload).is_err());
    }

    #[test]
    fn oversized_encode_fails_fast_with_a_cause() {
        // the writer must refuse a too-big frame (with a message) rather
        // than wrap the length prefix and desync the peer
        let payload = vec![0u8; MAX_FRAME_LEN as usize + 1];
        let mut sink = Vec::new();
        let err = write_frame(&mut sink, T_PING, &payload).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidInput);
        assert!(sink.is_empty(), "nothing may be written after the refusal");
    }

    #[test]
    fn hostile_feature_count_is_rejected_without_allocation() {
        // a row claiming u32::MAX features must fail fast, not allocate
        let mut payload = Vec::new();
        put_tenant(&mut payload, None);
        put_u32(&mut payload, u32::MAX);
        assert!(decode_request(T_PREDICT, &payload).is_err());
    }

    fn sample_trace() -> TraceEntry {
        TraceEntry {
            id: 42,
            tenant: Some("digits".into()),
            die: 3,
            pjrt: true,
            passes: 4,
            queue_us: 120,
            batch_us: 80,
            compute_us: 950,
            total_us: 1151,
            outcome: TraceOutcome::Ok,
        }
    }

    #[test]
    fn trace_frames_roundtrip_via_io() {
        let mut codec = FrameCodec;
        let req = Request::Trace { last: 16 };
        let mut buf = Vec::new();
        codec.write_request(&mut buf, &req).unwrap();
        let mut r: &[u8] = &buf;
        match codec.read_request(&mut r).unwrap() {
            Decoded::Request(back) => assert_eq!(back, req),
            other => panic!("{other:?}"),
        }

        let mut dropped = sample_trace();
        dropped.tenant = None;
        dropped.pjrt = false;
        dropped.outcome = TraceOutcome::DroppedUnknownTenant;
        let resp = Response::Trace(vec![sample_trace(), dropped]);
        let mut buf = Vec::new();
        codec.write_response(&mut buf, &resp).unwrap();
        let mut r: &[u8] = &buf;
        assert_eq!(codec.read_response(&mut r, &req).unwrap(), Some(resp));
    }

    #[test]
    fn snapshot_frames_roundtrip_via_io() {
        let mut codec = FrameCodec;
        let req = Request::Snapshot;
        let mut buf = Vec::new();
        codec.write_request(&mut buf, &req).unwrap();
        let mut r: &[u8] = &buf;
        match codec.read_request(&mut r).unwrap() {
            Decoded::Request(back) => assert_eq!(back, req),
            other => panic!("{other:?}"),
        }

        let resp = Response::Snapshot(StatsSnapshot::sample());
        let mut buf = Vec::new();
        codec.write_response(&mut buf, &resp).unwrap();
        let mut r: &[u8] = &buf;
        assert_eq!(codec.read_response(&mut r, &req).unwrap(), Some(resp));
    }

    #[test]
    fn hostile_trace_and_tenant_counts_are_rejected() {
        let mut payload = Vec::new();
        put_u32(&mut payload, u32::MAX);
        assert!(decode_response(R_TRACE, &payload).is_err());

        // a snapshot whose tenant count overruns the frame: with no
        // tenants or occupancy encoded, the tail is tenant count (4) +
        // occupancy count (4) + slo_breaches (8), so the tenant count
        // sits 16 bytes from the end
        let mut s = StatsSnapshot::sample();
        s.tenants.clear();
        s.occupancy.clear();
        let (_, mut hostile) = encode_response(&Response::Snapshot(s.clone()));
        let n = hostile.len();
        hostile[n - 16..n - 12].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_response(R_SNAPSHOT, &hostile).unwrap_err();
        assert!(err.contains("tenant count"), "{err}");

        // ... and a hostile occupancy count (12 bytes from the end)
        let (_, mut hostile) = encode_response(&Response::Snapshot(s));
        let n = hostile.len();
        hostile[n - 12..n - 8].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_response(R_SNAPSHOT, &hostile).unwrap_err();
        assert!(err.contains("occupancy count"), "{err}");

        // and trailing bytes after a well-formed snapshot are rejected
        let (_, mut payload) = encode_response(&Response::Snapshot(StatsSnapshot::sample()));
        payload.push(0);
        assert!(decode_response(R_SNAPSHOT, &payload).is_err());
    }

    #[test]
    fn unknown_trace_outcome_code_is_rejected() {
        let mut payload = Vec::new();
        put_u32(&mut payload, 1);
        put_trace_entry(&mut payload, &sample_trace());
        let n = payload.len();
        payload[n - 1] = 9; // no such outcome
        assert!(decode_response(R_TRACE, &payload).is_err());
    }

    #[test]
    fn governor_frames_roundtrip_via_io() {
        let mut codec = FrameCodec;
        let req = Request::Governor;
        let mut buf = Vec::new();
        codec.write_request(&mut buf, &req).unwrap();
        let mut r: &[u8] = &buf;
        match codec.read_request(&mut r).unwrap() {
            Decoded::Request(back) => assert_eq!(back, req),
            other => panic!("{other:?}"),
        }

        let resp = Response::Governor("die0: b=6 price=42fJ".into());
        let mut buf = Vec::new();
        codec.write_response(&mut buf, &resp).unwrap();
        let mut r: &[u8] = &buf;
        assert_eq!(codec.read_response(&mut r, &req).unwrap(), Some(resp));
    }

    fn sample_timeline() -> Vec<TimelineEvent> {
        vec![
            TimelineEvent {
                die: 0,
                seg: Segment::Idle,
                start_us: 0,
                end_us: 500,
                req_id: None,
            },
            TimelineEvent {
                die: 0,
                seg: Segment::BatchWait,
                start_us: 500,
                end_us: 620,
                req_id: Some(41),
            },
            TimelineEvent {
                die: 1,
                seg: Segment::RotationPass,
                start_us: 620,
                end_us: 620,
                req_id: Some(42),
            },
        ]
    }

    #[test]
    fn timeline_frames_roundtrip_via_io() {
        let mut codec = FrameCodec;
        let req = Request::Timeline { last: 256 };
        let mut buf = Vec::new();
        codec.write_request(&mut buf, &req).unwrap();
        let mut r: &[u8] = &buf;
        match codec.read_request(&mut r).unwrap() {
            Decoded::Request(back) => assert_eq!(back, req),
            other => panic!("{other:?}"),
        }

        let resp = Response::Timeline(sample_timeline());
        let mut buf = Vec::new();
        codec.write_response(&mut buf, &resp).unwrap();
        let mut r: &[u8] = &buf;
        assert_eq!(codec.read_response(&mut r, &req).unwrap(), Some(resp));

        let empty = Response::Timeline(Vec::new());
        let mut buf = Vec::new();
        codec.write_response(&mut buf, &empty).unwrap();
        let mut r: &[u8] = &buf;
        assert_eq!(codec.read_response(&mut r, &req).unwrap(), Some(empty));
    }

    #[test]
    fn hostile_timeline_count_and_bad_segment_are_rejected() {
        // a frame claiming u32::MAX events must fail fast, not allocate
        let mut payload = Vec::new();
        put_u32(&mut payload, u32::MAX);
        let err = decode_response(R_TIMELINE, &payload).unwrap_err();
        assert!(err.contains("timeline count"), "{err}");

        // an in-range event with an unknown segment code is rejected
        let mut payload = Vec::new();
        put_u32(&mut payload, 1);
        put_timeline_event(&mut payload, &sample_timeline()[0]);
        payload[8] = 9; // segment byte: 4 (count) + 4 (die) in
        let err = decode_response(R_TIMELINE, &payload).unwrap_err();
        assert!(err.contains("segment code"), "{err}");

        // and trailing bytes after a well-formed list are rejected
        let (_, mut payload) = encode_response(&Response::Timeline(sample_timeline()));
        payload.push(0);
        assert!(decode_response(R_TIMELINE, &payload).is_err());
    }

    #[test]
    fn hostile_governor_point_count_is_rejected() {
        // a snapshot claiming u32::MAX per-die points must fail fast;
        // with no points encoded the count sits right after the compute
        // stage: 4 (version) + 16*8 (counters) + 4*40 (stages) + 5*8
        // (governor counters) bytes in
        let mut s = StatsSnapshot::sample();
        s.governor.points.clear();
        s.tenants.clear();
        let (_, mut hostile) = encode_response(&Response::Snapshot(s));
        let off = 4 + 16 * 8 + 4 * 40 + 5 * 8;
        hostile[off..off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_response(R_SNAPSHOT, &hostile).unwrap_err();
        assert!(err.contains("point count"), "{err}");
    }

    #[test]
    fn snapshot_version_is_checked_on_decode() {
        let (_, mut payload) = encode_response(&Response::Snapshot(StatsSnapshot::sample()));
        payload[0..4].copy_from_slice(&99u32.to_le_bytes());
        let err = decode_response(R_SNAPSHOT, &payload).unwrap_err();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn hello_tenant_update_and_batch_stream_frames_roundtrip() {
        for req in [
            Request::Hello { token: "alpha-key".into() },
            Request::Hello { token: String::new() },
            Request::TenantUpdate {
                name: "slope".into(),
                features: vec![0.5, -0.25, 1.0],
                targets: vec![0.125],
            },
            Request::BatchStream {
                rows: vec![
                    PredictRow { tenant: None, features: vec![1.0, 2.0] },
                    PredictRow { tenant: Some("digits".into()), features: vec![] },
                ],
            },
        ] {
            let (ty, payload) = encode_request(&req);
            assert_eq!(decode_request(ty, &payload).unwrap(), Some(req));
            let mut trailing = payload.clone();
            trailing.push(0);
            assert!(decode_request(ty, &trailing).is_err());
        }
        for resp in [
            Response::HelloOk { tenants: vec!["*".into()] },
            Response::HelloOk { tenants: vec!["a".into(), "b".into()] },
            Response::HelloOk { tenants: vec![] },
            Response::Updated { name: "slope".into() },
        ] {
            let (ty, payload) = encode_response(&resp);
            assert_eq!(decode_response(ty, &payload).unwrap(), resp);
            let mut trailing = payload.clone();
            trailing.push(0);
            assert!(decode_response(ty, &trailing).is_err());
        }
        // a hostile scope count must fail fast, not allocate
        let mut payload = Vec::new();
        put_u32(&mut payload, u32::MAX);
        let err = decode_response(R_HELLO, &payload).unwrap_err();
        assert!(err.contains("scope count"), "{err}");
    }

    #[test]
    fn correlation_envelopes_roundtrip_and_reject_nesting() {
        let req = Request::Predict { tenant: Some("slope".into()), features: vec![0.5] };
        let (ty, payload) = encode_correlated_request(7, &req);
        assert_eq!(ty, T_CORR);
        assert_eq!(decode_correlated_request(&payload).unwrap(), (7, req.clone()));

        let resp = Response::Predict(Prediction { label: 1, score: 0.25, tenant: None });
        let (rty, rpayload) = encode_correlated_response(7, &resp);
        assert_eq!(rty, R_CORR);
        assert_eq!(decode_correlated_response(&rpayload).unwrap(), (7, resp));

        // nesting, handshake and quit are refused inside an envelope
        let (_, nested) = encode_correlated_request(8, &req);
        let mut outer = Vec::new();
        put_u64(&mut outer, 9);
        outer.push(T_CORR);
        outer.extend_from_slice(&nested);
        assert!(decode_correlated_request(&outer).is_err());
        for bad in [T_HELLO, T_QUIT] {
            let mut env = Vec::new();
            put_u64(&mut env, 1);
            env.push(bad);
            assert!(decode_correlated_request(&env).is_err());
        }
        let mut env = Vec::new();
        put_u64(&mut env, 1);
        env.push(R_CORR);
        assert!(decode_correlated_response(&env).is_err());
        // and a truncated envelope (no inner type byte) is rejected
        let mut short = Vec::new();
        put_u64(&mut short, 1);
        assert!(decode_correlated_request(&short).is_err());
    }

    #[test]
    fn stream_row_and_end_frames_roundtrip() {
        let p = Prediction { label: -1, score: 0.75, tenant: Some("slope".into()) };
        let (ty, payload) = encode_stream_row(11, 3, &p);
        assert_eq!(ty, R_STREAM_ROW);
        assert_eq!(decode_stream_row(&payload).unwrap(), (11, 3, p));
        let mut trailing = payload.clone();
        trailing.push(0);
        assert!(decode_stream_row(&trailing).is_err());

        let (ty, payload) = encode_stream_end(11, 64, 384);
        assert_eq!(ty, R_STREAM_END);
        assert_eq!(decode_stream_end(&payload).unwrap(), (11, 64, 384));
        let mut trailing = payload.clone();
        trailing.push(0);
        assert!(decode_stream_end(&trailing).is_err());
        assert!(decode_stream_end(&payload[..11]).is_err());
    }

    #[test]
    fn take_frame_parses_incrementally_and_reports_consumption() {
        let req = Request::Predict { tenant: None, features: vec![0.5, -0.5] };
        let (ty, payload) = encode_request(&req);
        let bytes = frame_bytes(ty, &payload).unwrap();
        // every strict prefix needs more bytes; the full buffer parses
        for n in 0..bytes.len() {
            assert!(take_frame(&bytes[..n]).unwrap().is_none(), "prefix {n}");
        }
        let (got_ty, got_payload, consumed) = take_frame(&bytes).unwrap().unwrap();
        assert_eq!((got_ty, consumed), (ty, bytes.len()));
        assert_eq!(decode_request(got_ty, &got_payload).unwrap(), Some(req));
        // trailing bytes of a second frame are left unconsumed
        let mut two = bytes.clone();
        two.extend_from_slice(&bytes);
        let (_, _, consumed) = take_frame(&two).unwrap().unwrap();
        assert_eq!(consumed, bytes.len());
        assert!(take_frame(&two[consumed..]).unwrap().is_some());
        // bad magic and oversized prefixes are hard errors
        assert!(take_frame(b"PING\n").is_err());
        let mut huge = vec![FRAME_MAGIC, T_PING];
        huge.extend_from_slice(&(MAX_FRAME_LEN + 1).to_le_bytes());
        assert!(take_frame(&huge).is_err());
    }
}
