//! Typed serving protocol (DESIGN.md §15): one request/response
//! vocabulary for every entry point into the coordinator.
//!
//! Three callers speak it:
//!
//!   * the TCP front end (`coordinator::server`), which negotiates a
//!     wire codec per connection by sniffing the first byte;
//!   * the [`crate::client::Client`] SDK, over either wire codec or
//!     in-process;
//!   * library users, by calling `Coordinator::handle` directly.
//!
//! Two wire encodings implement the [`Codec`] trait:
//!
//!   * [`LineCodec`] — protocol **v0**, the original newline-terminated
//!     ASCII grammar (`CLASSIFY x1,x2,...` -> `OK <label> <score>`),
//!     kept bit-compatible so pre-protocol clients keep working. It has
//!     no batch frame: a batch degenerates to one round-trip per row.
//!   * [`FrameCodec`] — protocol **v1**, length-prefixed binary frames
//!     opening with [`frame::FRAME_MAGIC`] (a byte no ASCII command
//!     starts with — that is the whole negotiation). v1 carries
//!     [`Request::BatchPredict`]: many rows, each addressed to its own
//!     tenant, submitted to the batcher as ONE unit so the hidden-layer
//!     pass is amortised across the batch.
//!
//! The enums derive `PartialEq` so codecs are property-testable:
//! `decode(encode(x)) == x` for every frame type (tests/proptests.rs).

pub mod frame;
pub mod line;
pub mod stats;

pub use frame::FrameCodec;
pub use line::LineCodec;
pub use stats::{
    DieOccupancy, GovernorStats, Segment, StageStats, StatsSnapshot, TenantStats, TimelineEvent,
    TraceEntry, TraceOutcome, SEGMENTS,
};

use std::io::{BufRead, Write};

/// One row of a (batch) prediction: which tenant's head scores it
/// (`None` = the fleet's default head) and the feature vector.
#[derive(Clone, Debug, PartialEq)]
pub struct PredictRow {
    pub tenant: Option<String>,
    pub features: Vec<f64>,
}

/// Everything a client can ask of the serving fleet. `QUIT` is
/// deliberately absent: closing a connection is transport business and
/// surfaces as [`Decoded::Quit`], never as a dispatchable request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Liveness probe.
    Ping,
    /// One-line metrics snapshot.
    Stats,
    /// Per-die lifecycle gauges + fleet counters.
    Health,
    /// Tenant directory one-liner.
    Models,
    /// Pull a die from rotation for recalibration.
    Drain { die: usize },
    /// Score one row through one tenant's head (`None` = default).
    Predict {
        tenant: Option<String>,
        features: Vec<f64>,
    },
    /// Score many rows — each with its own tenant — as ONE batcher
    /// submission (v1 only on the wire; v0 clients fall back to
    /// row-per-round-trip).
    BatchPredict { rows: Vec<PredictRow> },
    /// Train + install a tenant fleet-wide from a named dataset.
    Register {
        name: String,
        dataset: String,
        seed: u64,
    },
    /// Drop a tenant fleet-wide.
    Unregister { name: String },
    /// Dump the newest `last` entries from the flight recorder
    /// (DESIGN.md §16). The v0 spelling is `TRACE [n]`.
    Trace { last: usize },
    /// One consistent [`StatsSnapshot`] as a typed value (v1 only; v0
    /// clients read the rendered `STATS` line instead).
    Snapshot,
    /// Governor status one-liner (DESIGN.md §17): enabled/disabled,
    /// per-die operating points, move counters, energy saved. The v0
    /// spelling is `GOVERNOR`.
    Governor,
    /// Dump the newest `last` stamped timeline intervals from the
    /// fleet profiler (DESIGN.md §19), oldest first — the raw material
    /// for Chrome trace-event export (v1 only; v0 has no spelling).
    Timeline { last: usize },
    /// Connection handshake (DESIGN.md §20): present `token` and bind
    /// the connection to the tenant scope it grants. Outside a
    /// connection (in-process, library) it validates the token and
    /// reports the scope. v1 only; never rides a correlation envelope.
    Hello { token: String },
    /// One labelled OS-ELM row streamed into a registered tenant's
    /// heads via the shared-P update path (DESIGN.md §14, §20).
    /// `targets` carries one value per head.
    TenantUpdate {
        name: String,
        features: Vec<f64>,
        targets: Vec<f64>,
    },
    /// [`Request::BatchPredict`] asking for streamed replies: the
    /// reactor answers each row as its die finishes (`R_STREAM_ROW`
    /// frames in completion order, then `R_STREAM_END`). Blocking
    /// transports answer it like a buffered batch (v1 only).
    BatchStream { rows: Vec<PredictRow> },
}

/// One scored row, as the protocol reports it.
#[derive(Clone, Debug, PartialEq)]
pub struct Prediction {
    /// ±1 for binary heads, the argmax class for multi-class tenants,
    /// 0 for regression.
    pub label: i8,
    /// Raw second-stage score (training units for tenant heads).
    pub score: f64,
    /// Which tenant's head produced it (`None` = the default head).
    pub tenant: Option<String>,
}

/// Every answer the dispatcher can give. Exactly one variant answers
/// each [`Request`] variant; [`Response::Error`] answers any of them.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Pong,
    Stats(String),
    Health(String),
    Models(String),
    Draining { die: usize },
    Predict(Prediction),
    Batch(Vec<Prediction>),
    Registered {
        name: String,
        /// `Task` rendering, e.g. `classification/10` or `regression`.
        task: String,
        /// Mean chip-in-the-loop train score across dies.
        score: f64,
    },
    Unregistered { name: String },
    /// Flight-recorder dump, newest first.
    Trace(Vec<TraceEntry>),
    /// The structured stats export.
    Snapshot(StatsSnapshot),
    /// Governor status one-liner (same String-report shape as
    /// [`Response::Health`], so it rides both wire versions).
    Governor(String),
    /// Timeline profiler dump, oldest first (v1 only).
    Timeline(Vec<TimelineEvent>),
    Error(String),
    /// Handshake accepted: the granted tenant scope, `["*"]` when the
    /// token is unrestricted (DESIGN.md §20).
    HelloOk { tenants: Vec<String> },
    /// A [`Request::TenantUpdate`] was applied on every die.
    Updated { name: String },
}

/// Outcome of reading one request off a transport.
#[derive(Debug)]
pub enum Decoded {
    /// A well-formed request, ready for `Coordinator::handle`.
    Request(Request),
    /// Recoverable decode failure: the stream stays in sync; answer
    /// with `Response::Error(msg)` and keep the connection.
    Malformed(String),
    /// The peer asked to close (v0 `QUIT` line / v1 quit frame).
    Quit,
    /// Clean end of stream.
    Eof,
}

/// A wire encoding of the typed protocol. Server side reads requests
/// and writes responses; client side does the reverse. `read_response`
/// takes the request it answers because v0 replies are not
/// self-describing (`OK 1 0.5` only means "label 1, score 0.5" if you
/// know you asked `CLASSIFY`); [`FrameCodec`] ignores the hint.
pub trait Codec: Send {
    /// Protocol version: 0 = ASCII lines, 1 = binary frames.
    fn version(&self) -> u8;
    /// Server: read the next request (blocking; a transport read
    /// timeout surfaces as `Err` and should close the connection).
    fn read_request(&mut self, r: &mut dyn BufRead) -> std::io::Result<Decoded>;
    /// Server: write one response.
    fn write_response(&mut self, w: &mut dyn Write, resp: &Response) -> std::io::Result<()>;
    /// Client: write one request. Requests the version cannot carry
    /// (v0 `BatchPredict`) fail with `ErrorKind::InvalidInput`.
    fn write_request(&mut self, w: &mut dyn Write, req: &Request) -> std::io::Result<()>;
    /// Client: read the response to `expect`. `Ok(None)` = server hung up.
    fn read_response(
        &mut self,
        r: &mut dyn BufRead,
        expect: &Request,
    ) -> std::io::Result<Option<Response>>;
    /// Client: announce a clean close.
    fn write_quit(&mut self, w: &mut dyn Write) -> std::io::Result<()>;
}

/// Parse a comma-separated feature list (the v0 grammar's `x1,x2,...`;
/// also the CLI's `--row` argument).
pub fn parse_features(text: &str) -> Result<Vec<f64>, String> {
    text.split(',')
        .map(|t| t.trim().parse::<f64>().map_err(|e| format!("bad features: {e}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_features_accepts_and_rejects() {
        assert_eq!(parse_features("0.5,-1, 0.25").unwrap(), vec![0.5, -1.0, 0.25]);
        let err = parse_features("0.1,bogus").unwrap_err();
        assert!(err.starts_with("bad features:"), "{err}");
        assert!(parse_features("").is_err(), "empty text is one empty token");
        assert!(parse_features("1,,2").is_err());
    }

    #[test]
    fn typed_values_compare_structurally() {
        let a = Request::Predict { tenant: None, features: vec![0.1] };
        let b = Request::Predict { tenant: None, features: vec![0.1] };
        assert_eq!(a, b);
        let c = Request::Predict { tenant: Some("t".into()), features: vec![0.1] };
        assert_ne!(a, c);
    }
}
