//! Structured telemetry export (DESIGN.md §16): the versioned
//! [`StatsSnapshot`] every observer shares, plus the flight-recorder
//! [`TraceEntry`] schema.
//!
//! One snapshot is taken in a single pass over the coordinator's
//! metrics (`Metrics::snapshot`), then rendered three ways without
//! re-reading any atomic:
//!
//!   * the classic one-line `STATS` string (v0 clients, humans);
//!   * JSON (`to_json` / `from_json`) for `velm client stats --format
//!     json` and the `BENCH_6.json` recorder;
//!   * Prometheus-style text (`to_prometheus`) for scrape endpoints.
//!
//! It also crosses the v1 wire as a typed frame
//! (`Response::Snapshot`), so the client SDK and tests never scrape
//! strings. All derived rates (requests/s, pJ/MAC) are computed from
//! the snapshot's own fields — torn reads cannot manufacture them.

use crate::util::json::{self, Value};

/// Version stamp carried by every exported snapshot. Bump when a field
/// is added/renamed so recorded trajectories stay interpretable.
/// v2 added the [`GovernorStats`] block (DESIGN.md §17); v3 added the
/// per-die occupancy block, tenant busy time and the governor's SLO
/// breach counter (DESIGN.md §19).
pub const SNAPSHOT_VERSION: u32 = 3;

/// Number of timeline lifecycle segments a die's wall clock is split
/// into — the length of every per-die occupancy vector.
pub const SEGMENTS: usize = 7;

/// One lifecycle segment of a serving die's wall clock (DESIGN.md
/// §19). Workers stamp these contiguously: every instant of a die
/// thread's life belongs to exactly one segment, so the per-die
/// accumulated times tile the timeline and occupancy fractions sum
/// to 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Segment {
    /// Parked on the empty batcher queue, no work pending.
    Idle,
    /// First row arrived; holding the batch window open for more.
    BatchWait,
    /// Analog conversions: driving the hidden layer forward (the
    /// physical counter window, DAC load -> counter read).
    Convert,
    /// Extra rotation passes a virtual die spends re-using its
    /// physical columns (Section V; 0-width on physical dies).
    RotationPass,
    /// Digital transfer: scoring rows against output heads and
    /// writing replies back.
    Transfer,
    /// Control-plane work: set-env, renormalisation, tenant
    /// register/unregister, online updates, retunes.
    Control,
    /// Fleet-health work: probe reads and chip-in-the-loop refits.
    ProbeRefit,
}

impl Segment {
    /// Every segment, in wire-code order.
    pub const ALL: [Segment; SEGMENTS] = [
        Segment::Idle,
        Segment::BatchWait,
        Segment::Convert,
        Segment::RotationPass,
        Segment::Transfer,
        Segment::Control,
        Segment::ProbeRefit,
    ];

    /// Stable wire code (v1 timeline frames) — also the index into
    /// per-die occupancy vectors.
    pub fn code(self) -> u8 {
        match self {
            Segment::Idle => 0,
            Segment::BatchWait => 1,
            Segment::Convert => 2,
            Segment::RotationPass => 3,
            Segment::Transfer => 4,
            Segment::Control => 5,
            Segment::ProbeRefit => 6,
        }
    }

    /// Inverse of [`Segment::code`].
    pub fn from_code(code: u8) -> Option<Segment> {
        Segment::ALL.get(code as usize).copied()
    }

    /// Stable snake_case name (JSON / Prometheus labels / Chrome
    /// trace track names).
    pub fn name(self) -> &'static str {
        match self {
            Segment::Idle => "idle",
            Segment::BatchWait => "batch_wait",
            Segment::Convert => "convert",
            Segment::RotationPass => "rotation_pass",
            Segment::Transfer => "transfer",
            Segment::Control => "control",
            Segment::ProbeRefit => "probe_refit",
        }
    }
}

impl std::fmt::Display for Segment {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One stamped interval of a die's timeline: `[start_us, end_us)`
/// microseconds from the coordinator's profiling epoch, spent in one
/// [`Segment`]. `req_id` carries the first request id of the batch the
/// interval worked on (`None` for idle/control intervals) so Chrome
/// flow events can link a request's path batcher -> worker.
#[derive(Clone, Debug, PartialEq)]
pub struct TimelineEvent {
    /// Die (worker index) whose clock this interval belongs to.
    pub die: u32,
    pub seg: Segment,
    /// Microseconds from the profiling epoch, inclusive.
    pub start_us: u64,
    /// Microseconds from the profiling epoch, exclusive; `>= start_us`.
    pub end_us: u64,
    /// First request id served in this interval, when any.
    pub req_id: Option<u64>,
}

impl std::fmt::Display for TimelineEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "die={} seg={} start={}us end={}us req={}",
            self.die,
            self.seg,
            self.start_us,
            self.end_us,
            self.req_id.map_or("-".into(), |id| id.to_string()),
        )
    }
}

/// Accumulated per-die segment times — the exact integer ledger the
/// occupancy fractions are derived from. Microsecond counts come from
/// contiguous stamps, so they tile the die's profiled wall clock with
/// no gaps or overlaps.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DieOccupancy {
    /// Die (worker index).
    pub die: u32,
    /// Accumulated microseconds per segment, indexed by
    /// [`Segment::code`].
    pub seg_us: [u64; SEGMENTS],
}

impl DieOccupancy {
    /// Total profiled microseconds on this die.
    pub fn total_us(&self) -> u64 {
        self.seg_us.iter().sum()
    }

    /// Occupancy fractions per segment. Sums to 1.0 (within f64
    /// rounding, < 1e-9) whenever any time has been profiled; all
    /// zeros before the first stamp.
    pub fn fractions(&self) -> [f64; SEGMENTS] {
        let total = self.total_us();
        let mut out = [0.0; SEGMENTS];
        if total > 0 {
            for (f, &us) in out.iter_mut().zip(&self.seg_us) {
                *f = us as f64 / total as f64;
            }
        }
        out
    }
}

/// One latency distribution, reduced to the fields observers need.
/// Percentiles come from the 32-bucket log2 histogram (same
/// interpolation as the live `LatencyHist`), so they are estimates
/// with at-most-half-bucket bias, not exact order statistics.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StageStats {
    /// Samples recorded.
    pub count: u64,
    /// Sum of all samples, microseconds.
    pub sum_us: u64,
    pub p50_us: u64,
    pub p90_us: u64,
    pub p99_us: u64,
}

impl StageStats {
    /// Mean sample, microseconds (0 when empty).
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    fn to_value(&self) -> Value {
        Value::Obj(vec![
            ("count".into(), Value::Num(self.count as f64)),
            ("sum_us".into(), Value::Num(self.sum_us as f64)),
            ("p50_us".into(), Value::Num(self.p50_us as f64)),
            ("p90_us".into(), Value::Num(self.p90_us as f64)),
            ("p99_us".into(), Value::Num(self.p99_us as f64)),
        ])
    }

    fn from_value(v: &Value) -> Result<StageStats, String> {
        let field = |k: &str| {
            v.get(k)
                .and_then(Value::as_u64)
                .ok_or_else(|| format!("stage stats missing '{k}'"))
        };
        Ok(StageStats {
            count: field("count")?,
            sum_us: field("sum_us")?,
            p50_us: field("p50_us")?,
            p90_us: field("p90_us")?,
            p99_us: field("p99_us")?,
        })
    }
}

/// What the traffic-adaptive governor (DESIGN.md §17) has done so far:
/// tick/move counters, the cumulative modelled energy it saved versus
/// every die holding its boot operating point, and where each die sits
/// right now (counter bits). All counters are cumulative since boot;
/// `points` is a gauge (last observed per-die value, empty until the
/// governor's first tick or when it is disabled).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GovernorStats {
    /// Policy evaluations (one per governor period over all dies).
    pub ticks: u64,
    /// Moves toward the boot (high-throughput) point.
    pub raises: u64,
    /// Moves toward cheaper low-energy points.
    pub lowers: u64,
    /// Proposed moves vetoed (hysteresis budget, cooldown has its own
    /// Hold path, unhealthy die, failed retune).
    pub rejected: u64,
    /// Cumulative modelled energy saved vs the boot price, femtojoules:
    /// `sum over conversions of (boot_price - current_price)`.
    pub fj_saved: u64,
    /// Current counter bits per die, indexed by die id.
    pub points: Vec<u32>,
}

impl GovernorStats {
    fn to_value(&self) -> Value {
        Value::Obj(vec![
            ("ticks".into(), Value::Num(self.ticks as f64)),
            ("raises".into(), Value::Num(self.raises as f64)),
            ("lowers".into(), Value::Num(self.lowers as f64)),
            ("rejected".into(), Value::Num(self.rejected as f64)),
            ("fj_saved".into(), Value::Num(self.fj_saved as f64)),
            (
                "points".into(),
                Value::Arr(
                    self.points
                        .iter()
                        .map(|&b| Value::Num(b as f64))
                        .collect(),
                ),
            ),
        ])
    }

    fn from_value(v: &Value) -> Result<GovernorStats, String> {
        let field = |k: &str| {
            v.get(k)
                .and_then(Value::as_u64)
                .ok_or_else(|| format!("governor stats missing '{k}'"))
        };
        let mut points = Vec::new();
        for p in v
            .get("points")
            .and_then(Value::as_arr)
            .ok_or("governor stats missing 'points'")?
        {
            points.push(
                p.as_u64()
                    .ok_or("governor point is not an unsigned integer")? as u32,
            );
        }
        Ok(GovernorStats {
            ticks: field("ticks")?,
            raises: field("raises")?,
            lowers: field("lowers")?,
            rejected: field("rejected")?,
            fj_saved: field("fj_saved")?,
            points,
        })
    }
}

/// Per-tenant slice of the snapshot.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantStats {
    pub name: String,
    pub requests: u64,
    pub responses: u64,
    /// Modelled energy booked to this tenant's answered rows, fJ.
    pub energy_fj: u64,
    /// Die compute time attributed to this tenant's rows,
    /// microseconds — the numerator of its utilization share.
    pub busy_us: u64,
    /// Mean chip-in-the-loop training score across dies.
    pub train_score: f64,
    /// End-to-end latency of this tenant's answered rows.
    pub latency: StageStats,
}

/// One consistent picture of the serving fleet, taken in a single
/// pass. `responses <= requests` holds by construction (the snapshot
/// clamps), so readers never see torn counters.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StatsSnapshot {
    /// [`SNAPSHOT_VERSION`] at export time.
    pub version: u32,
    /// Microseconds since `Coordinator::start` returned.
    pub uptime_us: u64,
    /// Rows accepted for classification.
    pub requests: u64,
    /// Submit events (a v1 batch of k rows = 1 submission, k requests).
    pub submissions: u64,
    /// Rows answered. Clamped to `<= requests`.
    pub responses: u64,
    pub batches: u64,
    pub pjrt_batches: u64,
    pub sim_batches: u64,
    /// Rows that flowed through formed batches.
    pub batched_requests: u64,
    /// Analog conversions booked (virtual dies book passes-per-row).
    pub conversions: u64,
    pub probes: u64,
    pub renorms: u64,
    pub refits: u64,
    pub quarantines: u64,
    pub promotions: u64,
    /// Modelled energy of all booked conversions, femtojoules.
    pub energy_fj: u64,
    /// Modelled MACs performed by those conversions.
    pub macs: u64,
    /// End-to-end latency (submit -> reply), the classic histogram.
    pub latency: StageStats,
    /// Stage: submit -> pulled off the batcher queue.
    pub queue: StageStats,
    /// Stage: pulled -> batch dispatched to an engine.
    pub batch_wait: StageStats,
    /// Stage: engine dispatch -> row answered.
    pub compute: StageStats,
    /// Traffic-adaptive governor activity (DESIGN.md §17).
    pub governor: GovernorStats,
    pub tenants: Vec<TenantStats>,
    /// Per-die occupancy ledgers from the timeline profiler
    /// (DESIGN.md §19), indexed by die id. Empty until a worker's
    /// first stamp.
    pub occupancy: Vec<DieOccupancy>,
    /// Governor ticks that observed a windowed p99 above the latency
    /// SLO (fleet-wide or any tenant's), cumulative since boot.
    pub slo_breaches: u64,
}

impl StatsSnapshot {
    /// Modelled energy in joules.
    pub fn energy_j(&self) -> f64 {
        self.energy_fj as f64 * 1e-15
    }

    /// Fleet-wide modelled pJ/MAC (0 when no MACs have run).
    pub fn pj_per_mac(&self) -> f64 {
        if self.macs == 0 {
            0.0
        } else {
            (self.energy_fj as f64 * 1e-3) / self.macs as f64
        }
    }

    /// Requests per second over the uptime window (0 before any time passes).
    pub fn requests_per_s(&self) -> f64 {
        if self.uptime_us == 0 {
            0.0
        } else {
            self.requests as f64 / (self.uptime_us as f64 * 1e-6)
        }
    }

    /// Conversions per second over the uptime window.
    pub fn conversions_per_s(&self) -> f64 {
        if self.uptime_us == 0 {
            0.0
        } else {
            self.conversions as f64 / (self.uptime_us as f64 * 1e-6)
        }
    }

    /// Serialize as one compact JSON object with deterministic field order.
    pub fn to_json(&self) -> String {
        let u = |n: u64| Value::Num(n as f64);
        let mut fields = vec![
            ("version".into(), u(self.version as u64)),
            ("uptime_us".into(), u(self.uptime_us)),
            ("requests".into(), u(self.requests)),
            ("submissions".into(), u(self.submissions)),
            ("responses".into(), u(self.responses)),
            ("batches".into(), u(self.batches)),
            ("pjrt_batches".into(), u(self.pjrt_batches)),
            ("sim_batches".into(), u(self.sim_batches)),
            ("batched_requests".into(), u(self.batched_requests)),
            ("conversions".into(), u(self.conversions)),
            ("probes".into(), u(self.probes)),
            ("renorms".into(), u(self.renorms)),
            ("refits".into(), u(self.refits)),
            ("quarantines".into(), u(self.quarantines)),
            ("promotions".into(), u(self.promotions)),
            ("energy_fj".into(), u(self.energy_fj)),
            ("macs".into(), u(self.macs)),
            ("pj_per_mac".into(), Value::Num(self.pj_per_mac())),
            ("requests_per_s".into(), Value::Num(self.requests_per_s())),
            (
                "conversions_per_s".into(),
                Value::Num(self.conversions_per_s()),
            ),
            ("latency".into(), self.latency.to_value()),
            ("queue".into(), self.queue.to_value()),
            ("batch_wait".into(), self.batch_wait.to_value()),
            ("compute".into(), self.compute.to_value()),
            ("governor".into(), self.governor.to_value()),
        ];
        let tenants = self
            .tenants
            .iter()
            .map(|t| {
                Value::Obj(vec![
                    ("name".into(), Value::Str(t.name.clone())),
                    ("requests".into(), u(t.requests)),
                    ("responses".into(), u(t.responses)),
                    ("energy_fj".into(), u(t.energy_fj)),
                    ("busy_us".into(), u(t.busy_us)),
                    ("train_score".into(), Value::Num(t.train_score)),
                    ("latency".into(), t.latency.to_value()),
                ])
            })
            .collect();
        fields.push(("tenants".into(), Value::Arr(tenants)));
        let occupancy = self
            .occupancy
            .iter()
            .map(|o| {
                Value::Obj(vec![
                    ("die".into(), u(o.die as u64)),
                    (
                        "seg_us".into(),
                        Value::Arr(o.seg_us.iter().map(|&us| u(us)).collect()),
                    ),
                ])
            })
            .collect();
        fields.push(("occupancy".into(), Value::Arr(occupancy)));
        fields.push(("slo_breaches".into(), u(self.slo_breaches)));
        let mut out = String::new();
        Value::Obj(fields).write(&mut out);
        out
    }

    /// Parse a `to_json` document back. Derived-rate fields are
    /// recomputed, not read, so they can never disagree with counters.
    pub fn from_json(text: &str) -> Result<StatsSnapshot, String> {
        let v = Value::parse(text)?;
        let field = |k: &str| {
            v.get(k)
                .and_then(Value::as_u64)
                .ok_or_else(|| format!("snapshot missing '{k}'"))
        };
        let stage = |k: &str| {
            StageStats::from_value(v.get(k).ok_or_else(|| format!("snapshot missing '{k}'"))?)
        };
        let version = field("version")? as u32;
        if version != SNAPSHOT_VERSION {
            return Err(format!(
                "snapshot version {version} unsupported (expected {SNAPSHOT_VERSION})"
            ));
        }
        let mut tenants = Vec::new();
        for t in v
            .get("tenants")
            .and_then(Value::as_arr)
            .ok_or("snapshot missing 'tenants'")?
        {
            let tf = |k: &str| {
                t.get(k)
                    .and_then(Value::as_u64)
                    .ok_or_else(|| format!("tenant missing '{k}'"))
            };
            tenants.push(TenantStats {
                name: t
                    .get("name")
                    .and_then(Value::as_str)
                    .ok_or("tenant missing 'name'")?
                    .to_string(),
                requests: tf("requests")?,
                responses: tf("responses")?,
                energy_fj: tf("energy_fj")?,
                busy_us: tf("busy_us")?,
                train_score: t
                    .get("train_score")
                    .and_then(Value::as_f64)
                    .ok_or("tenant missing 'train_score'")?,
                latency: StageStats::from_value(
                    t.get("latency").ok_or("tenant missing 'latency'")?,
                )?,
            });
        }
        let mut occupancy = Vec::new();
        for o in v
            .get("occupancy")
            .and_then(Value::as_arr)
            .ok_or("snapshot missing 'occupancy'")?
        {
            let die = o
                .get("die")
                .and_then(Value::as_u64)
                .ok_or("occupancy entry missing 'die'")? as u32;
            let arr = o
                .get("seg_us")
                .and_then(Value::as_arr)
                .ok_or("occupancy entry missing 'seg_us'")?;
            if arr.len() != SEGMENTS {
                return Err(format!(
                    "occupancy entry has {} segments (expected {SEGMENTS})",
                    arr.len()
                ));
            }
            let mut seg_us = [0u64; SEGMENTS];
            for (dst, val) in seg_us.iter_mut().zip(arr) {
                *dst = val
                    .as_u64()
                    .ok_or("occupancy segment time is not an unsigned integer")?;
            }
            occupancy.push(DieOccupancy { die, seg_us });
        }
        Ok(StatsSnapshot {
            version,
            uptime_us: field("uptime_us")?,
            requests: field("requests")?,
            submissions: field("submissions")?,
            responses: field("responses")?,
            batches: field("batches")?,
            pjrt_batches: field("pjrt_batches")?,
            sim_batches: field("sim_batches")?,
            batched_requests: field("batched_requests")?,
            conversions: field("conversions")?,
            probes: field("probes")?,
            renorms: field("renorms")?,
            refits: field("refits")?,
            quarantines: field("quarantines")?,
            promotions: field("promotions")?,
            energy_fj: field("energy_fj")?,
            macs: field("macs")?,
            latency: stage("latency")?,
            queue: stage("queue")?,
            batch_wait: stage("batch_wait")?,
            compute: stage("compute")?,
            governor: GovernorStats::from_value(
                v.get("governor").ok_or("snapshot missing 'governor'")?,
            )?,
            tenants,
            occupancy,
            slo_breaches: field("slo_breaches")?,
        })
    }

    /// Render as Prometheus exposition text (counters + gauges +
    /// per-stage quantile gauges), one scrape's worth.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut counter = |name: &str, v: u64| {
            out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
        };
        counter("velm_requests_total", self.requests);
        counter("velm_submissions_total", self.submissions);
        counter("velm_responses_total", self.responses);
        counter("velm_batches_total", self.batches);
        counter("velm_pjrt_batches_total", self.pjrt_batches);
        counter("velm_sim_batches_total", self.sim_batches);
        counter("velm_batched_requests_total", self.batched_requests);
        counter("velm_conversions_total", self.conversions);
        counter("velm_fleet_probes_total", self.probes);
        counter("velm_fleet_renorms_total", self.renorms);
        counter("velm_fleet_refits_total", self.refits);
        counter("velm_fleet_quarantines_total", self.quarantines);
        counter("velm_fleet_promotions_total", self.promotions);
        counter("velm_energy_femtojoules_total", self.energy_fj);
        counter("velm_macs_total", self.macs);
        counter("velm_governor_ticks_total", self.governor.ticks);
        counter("velm_governor_raises_total", self.governor.raises);
        counter("velm_governor_lowers_total", self.governor.lowers);
        counter("velm_governor_rejected_total", self.governor.rejected);
        counter(
            "velm_governor_femtojoules_saved_total",
            self.governor.fj_saved,
        );
        counter("velm_governor_slo_breaches_total", self.slo_breaches);
        out.push_str(&format!(
            "# TYPE velm_uptime_seconds gauge\nvelm_uptime_seconds {}\n",
            self.uptime_us as f64 * 1e-6
        ));
        out.push_str(&format!(
            "# TYPE velm_pj_per_mac gauge\nvelm_pj_per_mac {}\n",
            self.pj_per_mac()
        ));
        out.push_str("# TYPE velm_stage_latency_us gauge\n");
        for (stage, s) in [
            ("total", &self.latency),
            ("queue", &self.queue),
            ("batch_wait", &self.batch_wait),
            ("compute", &self.compute),
        ] {
            for (q, v) in [("0.5", s.p50_us), ("0.9", s.p90_us), ("0.99", s.p99_us)] {
                out.push_str(&format!(
                    "velm_stage_latency_us{{stage=\"{stage}\",quantile=\"{q}\"}} {v}\n"
                ));
            }
        }
        out.push_str("# TYPE velm_stage_samples_total counter\n");
        for (stage, s) in [
            ("total", &self.latency),
            ("queue", &self.queue),
            ("batch_wait", &self.batch_wait),
            ("compute", &self.compute),
        ] {
            out.push_str(&format!(
                "velm_stage_samples_total{{stage=\"{stage}\"}} {}\n",
                s.count
            ));
        }
        if !self.governor.points.is_empty() {
            out.push_str("# TYPE velm_governor_point_bits gauge\n");
            for (die, b) in self.governor.points.iter().enumerate() {
                out.push_str(&format!(
                    "velm_governor_point_bits{{die=\"{die}\"}} {b}\n"
                ));
            }
        }
        if !self.occupancy.is_empty() {
            out.push_str("# TYPE velm_die_occupancy_ratio gauge\n");
            for o in &self.occupancy {
                for (seg, f) in Segment::ALL.iter().zip(o.fractions()) {
                    out.push_str(&format!(
                        "velm_die_occupancy_ratio{{die=\"{}\",segment=\"{}\"}} {f}\n",
                        o.die,
                        seg.name()
                    ));
                }
            }
            out.push_str("# TYPE velm_die_busy_us_total counter\n");
            for o in &self.occupancy {
                out.push_str(&format!(
                    "velm_die_busy_us_total{{die=\"{}\"}} {}\n",
                    o.die,
                    o.total_us()
                ));
            }
        }
        if !self.tenants.is_empty() {
            out.push_str("# TYPE velm_tenant_requests_total counter\n");
            for t in &self.tenants {
                out.push_str(&format!(
                    "velm_tenant_requests_total{{tenant={}}} {}\n",
                    prom_label(&t.name),
                    t.requests
                ));
            }
            out.push_str("# TYPE velm_tenant_responses_total counter\n");
            for t in &self.tenants {
                out.push_str(&format!(
                    "velm_tenant_responses_total{{tenant={}}} {}\n",
                    prom_label(&t.name),
                    t.responses
                ));
            }
            out.push_str("# TYPE velm_tenant_energy_femtojoules_total counter\n");
            for t in &self.tenants {
                out.push_str(&format!(
                    "velm_tenant_energy_femtojoules_total{{tenant={}}} {}\n",
                    prom_label(&t.name),
                    t.energy_fj
                ));
            }
            out.push_str("# TYPE velm_tenant_busy_us_total counter\n");
            for t in &self.tenants {
                out.push_str(&format!(
                    "velm_tenant_busy_us_total{{tenant={}}} {}\n",
                    prom_label(&t.name),
                    t.busy_us
                ));
            }
            // utilization share: this tenant's compute time over all
            // tenant-attributed compute time (a gauge in [0, 1])
            let busy_total: u64 = self.tenants.iter().map(|t| t.busy_us).sum();
            if busy_total > 0 {
                out.push_str("# TYPE velm_tenant_utilization_share gauge\n");
                for t in &self.tenants {
                    out.push_str(&format!(
                        "velm_tenant_utilization_share{{tenant={}}} {}\n",
                        prom_label(&t.name),
                        t.busy_us as f64 / busy_total as f64
                    ));
                }
            }
            out.push_str("# TYPE velm_tenant_latency_us gauge\n");
            for t in &self.tenants {
                for (q, v) in [
                    ("0.5", t.latency.p50_us),
                    ("0.9", t.latency.p90_us),
                    ("0.99", t.latency.p99_us),
                ] {
                    out.push_str(&format!(
                        "velm_tenant_latency_us{{tenant={},quantile=\"{q}\"}} {}\n",
                        prom_label(&t.name),
                        v
                    ));
                }
            }
        }
        out
    }
}

#[cfg(test)]
impl StatsSnapshot {
    /// A fully-populated fixture shared by the stats and frame tests.
    pub(crate) fn sample() -> StatsSnapshot {
        StatsSnapshot {
            version: SNAPSHOT_VERSION,
            uptime_us: 2_000_000,
            requests: 10,
            submissions: 4,
            responses: 9,
            batches: 3,
            pjrt_batches: 1,
            sim_batches: 2,
            batched_requests: 9,
            conversions: 54,
            probes: 2,
            renorms: 1,
            refits: 0,
            quarantines: 0,
            promotions: 0,
            energy_fj: 54_000,
            macs: 5400,
            latency: StageStats { count: 9, sum_us: 900, p50_us: 96, p90_us: 192, p99_us: 192 },
            queue: StageStats { count: 9, sum_us: 90, p50_us: 12, p90_us: 24, p99_us: 24 },
            batch_wait: StageStats { count: 9, sum_us: 45, p50_us: 6, p90_us: 6, p99_us: 6 },
            compute: StageStats { count: 9, sum_us: 765, p50_us: 80, p90_us: 160, p99_us: 160 },
            governor: GovernorStats {
                ticks: 12,
                raises: 2,
                lowers: 5,
                rejected: 1,
                fj_saved: 4_200,
                points: vec![14, 6],
            },
            tenants: vec![TenantStats {
                name: "digits π".into(),
                requests: 5,
                responses: 5,
                energy_fj: 30_000,
                busy_us: 400,
                train_score: 0.9375,
                latency: StageStats { count: 5, sum_us: 500, p50_us: 96, p90_us: 192, p99_us: 192 },
            }],
            occupancy: vec![
                DieOccupancy { die: 0, seg_us: [500, 100, 200, 0, 150, 40, 10] },
                DieOccupancy { die: 1, seg_us: [800, 50, 100, 30, 20, 0, 0] },
            ],
            slo_breaches: 1,
        }
    }
}

/// Quote a Prometheus label value (backslash, quote, newline escaped).
fn prom_label(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// How a traced request left the worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceOutcome {
    /// Scored and replied.
    Ok,
    /// Dropped: malformed feature vector.
    DroppedMalformed,
    /// Dropped: tenant tag not registered on the serving die.
    DroppedUnknownTenant,
    /// Governor event (not a request): the die moved to a cheaper
    /// operating point. `die` is the die, `passes` the new counter
    /// bits, `total_us` the new fJ/conversion price.
    GovernorLowered,
    /// Governor event (not a request): the die moved back toward its
    /// boot (high-throughput) point. Same field reuse as
    /// [`TraceOutcome::GovernorLowered`].
    GovernorRaised,
}

impl TraceOutcome {
    /// Stable wire code (v1 trace frames).
    pub fn code(self) -> u8 {
        match self {
            TraceOutcome::Ok => 0,
            TraceOutcome::DroppedMalformed => 1,
            TraceOutcome::DroppedUnknownTenant => 2,
            TraceOutcome::GovernorLowered => 3,
            TraceOutcome::GovernorRaised => 4,
        }
    }

    /// Inverse of [`TraceOutcome::code`].
    pub fn from_code(code: u8) -> Option<TraceOutcome> {
        match code {
            0 => Some(TraceOutcome::Ok),
            1 => Some(TraceOutcome::DroppedMalformed),
            2 => Some(TraceOutcome::DroppedUnknownTenant),
            3 => Some(TraceOutcome::GovernorLowered),
            4 => Some(TraceOutcome::GovernorRaised),
            _ => None,
        }
    }
}

impl std::fmt::Display for TraceOutcome {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            TraceOutcome::Ok => "ok",
            TraceOutcome::DroppedMalformed => "dropped:malformed",
            TraceOutcome::DroppedUnknownTenant => "dropped:unknown-tenant",
            TraceOutcome::GovernorLowered => "governor:lowered",
            TraceOutcome::GovernorRaised => "governor:raised",
        })
    }
}

/// One completed request's span record, as the flight recorder keeps
/// it and the `TRACE` verb dumps it. Stage micros are measured from
/// the same monotonic clock: `queue_us + batch_us + compute_us`
/// brackets `total_us` up to per-stage flooring (each stage floors to
/// whole microseconds, so the sum can undershoot by < 3 us).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEntry {
    /// Coordinator-assigned request id.
    pub id: u64,
    /// Tenant tag (`None` = default head).
    pub tenant: Option<String>,
    /// Die (worker index) that served the row.
    pub die: u32,
    /// Engine: true = PJRT batch path, false = chip-sim.
    pub pjrt: bool,
    /// Rotation passes the serving die spends per conversion.
    pub passes: u32,
    /// Submit -> pulled off the batcher queue.
    pub queue_us: u64,
    /// Pulled -> batch dispatched to the engine.
    pub batch_us: u64,
    /// Dispatch -> row answered (or dropped).
    pub compute_us: u64,
    /// Submit -> answered, the end-to-end span.
    pub total_us: u64,
    pub outcome: TraceOutcome,
}

impl std::fmt::Display for TraceEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "id={} tenant={} die={} engine={} passes={} queue={}us batch={}us compute={}us total={}us outcome={}",
            self.id,
            self.tenant.as_deref().unwrap_or("-"),
            self.die,
            if self.pjrt { "pjrt" } else { "chip-sim" },
            self.passes,
            self.queue_us,
            self.batch_us,
            self.compute_us,
            self.total_us,
            self.outcome,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> StatsSnapshot {
        StatsSnapshot::sample()
    }

    #[test]
    fn json_roundtrips_exactly() {
        let snap = sample();
        let parsed = StatsSnapshot::from_json(&snap.to_json()).unwrap();
        assert_eq!(parsed, snap);
    }

    #[test]
    fn derived_rates_follow_counters() {
        let snap = sample();
        assert!((snap.requests_per_s() - 5.0).abs() < 1e-12);
        assert!((snap.conversions_per_s() - 27.0).abs() < 1e-12);
        assert!((snap.pj_per_mac() - 10.0).abs() < 1e-12, "54000 fJ / 5400 MAC = 10 pJ/MAC");
        assert!((snap.energy_j() - 54e-12).abs() < 1e-24);
        let empty = StatsSnapshot::default();
        assert_eq!(empty.pj_per_mac(), 0.0);
        assert_eq!(empty.requests_per_s(), 0.0);
    }

    #[test]
    fn from_json_rejects_missing_fields_and_bad_version() {
        assert!(StatsSnapshot::from_json("{}").is_err());
        let mut snap = sample();
        snap.version = 99;
        assert!(StatsSnapshot::from_json(&snap.to_json()).is_err());
        assert!(StatsSnapshot::from_json("not json").is_err());
    }

    #[test]
    fn prometheus_text_has_counters_stages_and_tenants() {
        let text = sample().to_prometheus();
        assert!(text.contains("velm_requests_total 10\n"));
        assert!(text.contains("velm_conversions_total 54\n"));
        assert!(text.contains("velm_energy_femtojoules_total 54000\n"));
        assert!(text.contains("velm_stage_latency_us{stage=\"queue\",quantile=\"0.5\"} 12\n"));
        assert!(text.contains("velm_stage_samples_total{stage=\"compute\"} 9\n"));
        assert!(text.contains("velm_tenant_requests_total{tenant=\"digits π\"} 5\n"));
        assert!(text.contains("velm_pj_per_mac 10\n"));
    }

    #[test]
    fn trace_outcome_codes_roundtrip() {
        for o in [
            TraceOutcome::Ok,
            TraceOutcome::DroppedMalformed,
            TraceOutcome::DroppedUnknownTenant,
            TraceOutcome::GovernorLowered,
            TraceOutcome::GovernorRaised,
        ] {
            assert_eq!(TraceOutcome::from_code(o.code()), Some(o));
        }
        assert_eq!(TraceOutcome::from_code(9), None);
    }

    #[test]
    fn segment_codes_roundtrip_and_cover_all() {
        for (i, seg) in Segment::ALL.iter().enumerate() {
            assert_eq!(seg.code() as usize, i);
            assert_eq!(Segment::from_code(seg.code()), Some(*seg));
            assert!(!seg.name().is_empty());
        }
        assert_eq!(Segment::from_code(SEGMENTS as u8), None);
    }

    #[test]
    fn occupancy_fractions_sum_to_one() {
        let o = DieOccupancy { die: 0, seg_us: [7, 13, 0, 1, 997, 3, 11] };
        assert_eq!(o.total_us(), 1032);
        let sum: f64 = o.fractions().iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "fractions sum to {sum}");
        let empty = DieOccupancy::default();
        assert_eq!(empty.fractions(), [0.0; SEGMENTS]);
    }

    #[test]
    fn occupancy_and_slo_breaches_survive_json_and_reach_prometheus() {
        let snap = sample();
        let parsed = StatsSnapshot::from_json(&snap.to_json()).unwrap();
        assert_eq!(parsed.occupancy, snap.occupancy);
        assert_eq!(parsed.slo_breaches, 1);
        assert_eq!(parsed.tenants[0].busy_us, 400);
        let text = snap.to_prometheus();
        assert!(text.contains("velm_governor_slo_breaches_total 1\n"));
        assert!(text.contains("velm_die_occupancy_ratio{die=\"0\",segment=\"idle\"} 0.5\n"));
        assert!(text.contains("velm_die_busy_us_total{die=\"1\"} 1000\n"));
        assert!(text.contains("velm_tenant_busy_us_total{tenant=\"digits π\"} 400\n"));
        assert!(text.contains("velm_tenant_utilization_share{tenant=\"digits π\"} 1\n"));
    }

    #[test]
    fn governor_stats_survive_json_and_reach_prometheus() {
        let snap = sample();
        let parsed = StatsSnapshot::from_json(&snap.to_json()).unwrap();
        assert_eq!(parsed.governor, snap.governor);
        assert_eq!(parsed.governor.points, vec![14, 6]);
        let text = snap.to_prometheus();
        assert!(text.contains("velm_governor_ticks_total 12\n"));
        assert!(text.contains("velm_governor_lowers_total 5\n"));
        assert!(text.contains("velm_governor_femtojoules_saved_total 4200\n"));
        assert!(text.contains("velm_governor_point_bits{die=\"0\"} 14\n"));
        assert!(text.contains("velm_governor_point_bits{die=\"1\"} 6\n"));
        // disabled governor: no per-die gauge lines at all
        let mut quiet = sample();
        quiet.governor = GovernorStats::default();
        assert!(!quiet.to_prometheus().contains("velm_governor_point_bits{"));
    }

    #[test]
    fn trace_entry_renders_every_field() {
        let e = TraceEntry {
            id: 7,
            tenant: Some("digits".into()),
            die: 1,
            pjrt: false,
            passes: 6,
            queue_us: 10,
            batch_us: 5,
            compute_us: 85,
            total_us: 100,
            outcome: TraceOutcome::Ok,
        };
        let s = e.to_string();
        for needle in [
            "id=7", "tenant=digits", "die=1", "engine=chip-sim", "passes=6",
            "queue=10us", "batch=5us", "compute=85us", "total=100us", "outcome=ok",
        ] {
            assert!(s.contains(needle), "missing {needle} in {s}");
        }
    }
}
