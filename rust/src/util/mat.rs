//! Dense linear algebra substrate (row-major `f64`).
//!
//! No BLAS/LAPACK exists in the offline vendor set, so training-path
//! numerics (H assembly, Gram matrices, the ridge solve of eq. 3) are
//! built here: cache-blocked matmul with a packed-transpose inner kernel,
//! Cholesky factorisation for the SPD ridge system, and triangular solves.
//! The PJRT `train` artifact solves the same system on the XLA side;
//! integration tests pin the two against each other.

use crate::util::prng::Prng;

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    pub fn eye(n: usize) -> Self {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// I.i.d. uniform entries in `[lo, hi)` (software-ELM baseline weights).
    pub fn random_uniform(rows: usize, cols: usize, lo: f64, hi: f64, rng: &mut Prng) -> Self {
        let data = (0..rows * cols).map(|_| rng.range(lo, hi)).collect();
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// Matrix product `self * other`, blocked for cache reuse.
    ///
    /// Inner loop runs along contiguous rows of both `self` and a packed
    /// transpose-free layout: classic ikj order with row-slice FMA, which
    /// the compiler auto-vectorises. Good enough to keep the training path
    /// off the profile (see EXPERIMENTS.md §Perf).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        const BK: usize = 64;
        for k0 in (0..k).step_by(BK) {
            let k1 = (k0 + BK).min(k);
            for i in 0..m {
                let a_row = &self.data[i * k..(i + 1) * k];
                let o_row = &mut out.data[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let a = a_row[kk];
                    if a == 0.0 {
                        continue;
                    }
                    let b_row = &other.data[kk * n..(kk + 1) * n];
                    for (o, &b) in o_row.iter_mut().zip(b_row) {
                        *o += a * b;
                    }
                }
            }
        }
        out
    }

    /// `self * v` for a length-`cols` vector.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.cols);
        (0..self.rows)
            .map(|i| {
                self.row(i)
                    .iter()
                    .zip(v)
                    .map(|(a, b)| a * b)
                    .sum::<f64>()
            })
            .collect()
    }

    /// Gram matrix `selfᵀ * self` exploiting symmetry (training hot spot).
    pub fn gram(&self) -> Mat {
        let (n, l) = (self.rows, self.cols);
        let mut g = Mat::zeros(l, l);
        for r in 0..n {
            let row = self.row(r);
            for i in 0..l {
                let ri = row[i];
                if ri == 0.0 {
                    continue;
                }
                let grow = &mut g.data[i * l..(i + 1) * l];
                for j in i..l {
                    grow[j] += ri * row[j];
                }
            }
        }
        for i in 0..l {
            for j in 0..i {
                g.data[i * l + j] = g.data[j * l + i];
            }
        }
        g
    }

    pub fn add_diag(&mut self, v: f64) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self.data[i * self.cols + i] += v;
        }
    }

    pub fn scale(&mut self, s: f64) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Lossy narrowing for the PJRT FFI boundary.
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data: data.iter().map(|&x| x as f64).collect() }
    }
}

/// Cholesky factorisation `A = L Lᵀ` of an SPD matrix; returns lower `L`.
///
/// Errors if a pivot collapses (matrix not positive definite) — the ridge
/// term `I/C` guarantees this never triggers on the training path.
pub fn cholesky(a: &Mat) -> Result<Mat, String> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.get(i, j);
            for k in 0..j {
                sum -= l.get(i, k) * l.get(j, k);
            }
            if i == j {
                if sum <= 0.0 {
                    return Err(format!("cholesky: non-PD pivot {sum} at {i}"));
                }
                l.set(i, j, sum.sqrt());
            } else {
                l.set(i, j, sum / l.get(j, j));
            }
        }
    }
    Ok(l)
}

/// Solve `A x = b` for SPD `A` via Cholesky (forward + back substitution).
pub fn cholesky_solve(a: &Mat, b: &Mat) -> Result<Mat, String> {
    let l = cholesky(a)?;
    let n = a.rows;
    let m = b.cols;
    // forward: L y = b
    let mut y = b.clone();
    for i in 0..n {
        for c in 0..m {
            let mut v = y.get(i, c);
            for k in 0..i {
                v -= l.get(i, k) * y.get(k, c);
            }
            y.set(i, c, v / l.get(i, i));
        }
    }
    // backward: Lᵀ x = y
    let mut x = y;
    for i in (0..n).rev() {
        for c in 0..m {
            let mut v = x.get(i, c);
            for k in i + 1..n {
                v -= l.get(k, i) * x.get(k, c);
            }
            x.set(i, c, v / l.get(i, i));
        }
    }
    Ok(x)
}

/// Ridge-regularised least squares: `beta = (HᵀH + lam I)⁻¹ Hᵀ T` (eq. 3).
///
/// This is the ELM output-weight solve; `lam = 1/C` in the paper's ridge
/// notation. `t` may have multiple columns (one-vs-all multi-output).
pub fn ridge_solve(h: &Mat, t: &Mat, lam: f64) -> Result<Mat, String> {
    assert_eq!(h.rows, t.rows, "H and T row mismatch");
    let mut a = h.gram();
    a.add_diag(lam);
    let ht_t = h.transpose().matmul(t);
    cholesky_solve(&a, &ht_t)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_mat(r: usize, c: usize, seed: u64) -> Mat {
        let mut p = Prng::new(seed);
        Mat::from_fn(r, c, |_, _| p.gaussian())
    }

    #[test]
    fn matmul_identity() {
        let a = rand_mat(7, 7, 1);
        let i = Mat::eye(7);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-12);
        assert!(i.matmul(&a).max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn matmul_matches_naive() {
        let a = rand_mat(13, 29, 2);
        let b = rand_mat(29, 17, 3);
        let fast = a.matmul(&b);
        let naive = Mat::from_fn(13, 17, |i, j| {
            (0..29).map(|k| a.get(i, k) * b.get(k, j)).sum()
        });
        assert!(fast.max_abs_diff(&naive) < 1e-10);
    }

    #[test]
    fn gram_equals_explicit_transpose_product() {
        let h = rand_mat(40, 12, 4);
        let g = h.gram();
        let explicit = h.transpose().matmul(&h);
        assert!(g.max_abs_diff(&explicit) < 1e-10);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = rand_mat(9, 5, 5);
        let v: Vec<f64> = (0..5).map(|i| i as f64 - 2.0).collect();
        let mv = a.matvec(&v);
        let col = Mat { rows: 5, cols: 1, data: v.clone() };
        let mm = a.matmul(&col);
        for i in 0..9 {
            assert!((mv[i] - mm.get(i, 0)).abs() < 1e-12);
        }
    }

    #[test]
    fn cholesky_reconstructs() {
        let b = rand_mat(10, 10, 6);
        let mut a = b.gram();
        a.add_diag(1.0);
        let l = cholesky(&a).unwrap();
        let rec = l.matmul(&l.transpose());
        assert!(rec.max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Mat::eye(3);
        a.set(2, 2, -1.0);
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn solve_recovers_known_solution() {
        let b = rand_mat(12, 12, 7);
        let mut a = b.gram();
        a.add_diag(0.5);
        let x_true = rand_mat(12, 3, 8);
        let rhs = a.matmul(&x_true);
        let x = cholesky_solve(&a, &rhs).unwrap();
        assert!(x.max_abs_diff(&x_true) < 1e-8);
    }

    #[test]
    fn ridge_gradient_vanishes() {
        let h = rand_mat(50, 10, 9);
        let t = rand_mat(50, 1, 10);
        let lam = 0.3;
        let beta = ridge_solve(&h, &t, lam).unwrap();
        // gradient: Hᵀ(H beta - T) + lam beta == 0
        let resid = {
            let hb = h.matmul(&beta);
            Mat::from_fn(50, 1, |i, j| hb.get(i, j) - t.get(i, j))
        };
        let mut grad = h.transpose().matmul(&resid);
        for i in 0..10 {
            let g = grad.get(i, 0) + lam * beta.get(i, 0);
            grad.set(i, 0, g);
        }
        assert!(grad.frob_norm() < 1e-8, "gradient {}", grad.frob_norm());
    }

    #[test]
    fn ridge_shrinks_with_lambda() {
        let h = rand_mat(30, 8, 11);
        let t = rand_mat(30, 1, 12);
        let b_small = ridge_solve(&h, &t, 1e-6).unwrap();
        let b_big = ridge_solve(&h, &t, 1e3).unwrap();
        assert!(b_big.frob_norm() < b_small.frob_norm());
    }

    #[test]
    fn f32_roundtrip() {
        let a = rand_mat(4, 3, 13);
        let f = a.to_f32();
        let back = Mat::from_f32(4, 3, &f);
        assert!(a.max_abs_diff(&back) < 1e-6);
    }
}
