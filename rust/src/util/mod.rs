//! Shared substrates: deterministic PRNG, statistics, dense linear algebra.

pub mod mat;
pub mod prng;
pub mod stats;
