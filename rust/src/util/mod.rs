//! Shared substrates: deterministic PRNG, statistics, dense linear
//! algebra, and a minimal JSON value model.

pub mod json;
pub mod mat;
pub mod prng;
pub mod stats;
