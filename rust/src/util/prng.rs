//! Deterministic pseudo-random number generation for the whole stack.
//!
//! The offline vendor set has no `rand` crate, so we ship our own:
//! SplitMix64 for seeding and stream-splitting, xoshiro256** as the core
//! generator (Blackman & Vigna), Box-Muller for Gaussians and the
//! log-normal mismatch weights of eq. 12. Everything in the repository
//! that needs randomness threads a [`Prng`] explicitly — chips are
//! "fabricated" from a seed, so every experiment is bit-reproducible.

/// SplitMix64 step: the recommended seeder for xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator with convenience distributions.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
    /// Cached second Box-Muller variate.
    gauss_spare: Option<f64>,
}

impl Prng {
    /// Seed deterministically from a single `u64` via SplitMix64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s, gauss_spare: None }
    }

    /// Derive an independent child stream (e.g. one per chip / worker).
    pub fn split(&mut self, tag: u64) -> Prng {
        Prng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Next raw 64-bit output (xoshiro256**).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our use).
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (std::f64::consts::TAU * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    /// Normal with given mean / standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// Log-normal `exp(N(mu, sigma))` — the eq. 12 mismatch weight shape.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_moments() {
        let mut p = Prng::new(7);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| p.f64()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean {mean}");
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn gaussian_moments() {
        let mut p = Prng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| p.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn lognormal_median_is_one_for_zero_mu() {
        let mut p = Prng::new(13);
        let mut xs: Vec<f64> = (0..50_001).map(|_| p.lognormal(0.0, 0.6)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[xs.len() / 2];
        assert!((median - 1.0).abs() < 0.03, "median {median}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut p = Prng::new(3);
        let mut xs: Vec<usize> = (0..100).collect();
        p.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Prng::new(5);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
