//! Minimal JSON value model: parser + writer (no serde offline).
//!
//! Exists for the observability layer (DESIGN.md §16): the structured
//! [`crate::protocol::stats::StatsSnapshot`] export and the
//! `BENCH_6.json` schema validator need to read JSON back, and the
//! vendor set has no serde. This is a deliberately small, strict
//! recursive-descent parser: one value per document, trailing bytes
//! rejected, `\uXXXX` escapes (including surrogate pairs) decoded.
//! Numbers are f64 — integers are exact up to 2^53, far beyond any
//! counter a test ever reads back.

/// One JSON value. Objects preserve insertion order (`Vec` of pairs,
/// not a map) so writers emit deterministic field order.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Parse one JSON document. Trailing non-whitespace is an error.
    pub fn parse(text: &str) -> Result<Value, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing bytes at offset {}", p.pos));
        }
        Ok(v)
    }

    /// Object field lookup (first match; `None` for non-objects).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Non-negative integer-valued number within f64's exact range.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 9.007_199_254_740_992e15 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(items) => Some(items.as_slice()),
            _ => None,
        }
    }

    /// Serialize compactly (no whitespace) into `out`.
    pub fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => write_num(*n, out),
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl std::fmt::Display for Value {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

/// Write a number the way our schema expects: integer-valued f64s
/// (counters) print without a fraction, everything else via `{}`.
fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; null is the closest faithful rendering.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() <= 9.007_199_254_740_992e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

/// Append `s` as a quoted JSON string, escaping as required.
pub fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self
            .bytes
            .get(self.pos)
            .is_some_and(|b| matches!(b, b' ' | b'\t' | b'\n' | b'\r'))
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at offset {}", b as char, self.pos))
        }
    }

    fn eat_lit(&mut self, lit: &str) -> Result<(), String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(())
        } else {
            Err(format!("expected '{lit}' at offset {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        match self.peek() {
            Some(b'n') => self.eat_lit("null").map(|()| Value::Null),
            Some(b't') => self.eat_lit("true").map(|()| Value::Bool(true)),
            Some(b'f') => self.eat_lit("false").map(|()| Value::Bool(false)),
            Some(b'"') => self.string().map(Value::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(b) => Err(format!("unexpected byte 0x{b:02x} at offset {}", self.pos)),
            None => Err("unexpected end of input".into()),
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at offset {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.eat(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(fields));
                }
                _ => return Err(format!("expected ',' or '}}' at offset {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let start = self.pos;
            while self
                .peek()
                .is_some_and(|b| b != b'"' && b != b'\\' && b >= 0x20)
            {
                self.pos += 1;
            }
            if self.pos > start {
                let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                    .map_err(|e| format!("invalid utf-8 in string: {e}"))?;
                out.push_str(chunk);
            }
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    out.push(self.escape()?);
                }
                Some(b) => return Err(format!("raw control byte 0x{b:02x} in string")),
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn escape(&mut self) -> Result<char, String> {
        let b = self.peek().ok_or("unterminated escape")?;
        self.pos += 1;
        Ok(match b {
            b'"' => '"',
            b'\\' => '\\',
            b'/' => '/',
            b'b' => '\u{8}',
            b'f' => '\u{c}',
            b'n' => '\n',
            b'r' => '\r',
            b't' => '\t',
            b'u' => {
                let hi = self.hex4()?;
                if (0xD800..0xDC00).contains(&hi) {
                    // High surrogate: a low surrogate escape must follow.
                    self.eat_lit("\\u")?;
                    let lo = self.hex4()?;
                    if !(0xDC00..0xE000).contains(&lo) {
                        return Err("unpaired surrogate in \\u escape".into());
                    }
                    let cp = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                    char::from_u32(cp).ok_or("invalid surrogate pair")?
                } else {
                    char::from_u32(hi).ok_or("unpaired surrogate in \\u escape")?
                }
            }
            _ => return Err(format!("bad escape '\\{}'", b as char)),
        })
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.pos + 4 > self.bytes.len() {
            return Err("truncated \\u escape".into());
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| "non-ascii in \\u escape".to_string())?;
        let v = u32::from_str_radix(s, 16).map_err(|_| format!("bad \\u escape '{s}'"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .is_some_and(|b| b.is_ascii_digit() || matches!(b, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii slice");
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|e| format!("bad number '{text}': {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse(" true ").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("false").unwrap(), Value::Bool(false));
        assert_eq!(Value::parse("-2.5e2").unwrap(), Value::Num(-250.0));
        assert_eq!(Value::parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested_and_preserves_order() {
        let v = Value::parse(r#"{"b":[1,2,{"x":null}],"a":"y"}"#).unwrap();
        let arr = v.get("b").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_u64(), Some(1));
        assert_eq!(arr[2].get("x"), Some(&Value::Null));
        assert_eq!(v.get("a").unwrap().as_str(), Some("y"));
        match &v {
            Value::Obj(fields) => assert_eq!(fields[0].0, "b"),
            other => panic!("expected object, got {other:?}"),
        }
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Value::parse(r#""a\"b\\c\nd\u00e9 \ud83d\ude00""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndé 😀"));
        let mut out = String::new();
        v.write(&mut out);
        let back = Value::parse(&out).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn write_then_parse_roundtrips() {
        let v = Value::Obj(vec![
            ("n".into(), Value::Num(42.0)),
            ("f".into(), Value::Num(0.125)),
            ("s".into(), Value::Str("π: a\tb".into())),
            ("a".into(), Value::Arr(vec![Value::Bool(false), Value::Null])),
        ]);
        let mut out = String::new();
        v.write(&mut out);
        assert_eq!(Value::parse(&out).unwrap(), v);
        assert!(out.contains("\"n\":42"), "integers print without fraction: {out}");
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "", "{", "[1,", "{\"a\"}", "{\"a\":1,}", "tru", "\"unterminated",
            "1 2", "{}x", "\"\\q\"", "\"\\ud800\"", "nan",
        ] {
            assert!(Value::parse(bad).is_err(), "accepted malformed {bad:?}");
        }
    }

    #[test]
    fn u64_accessor_guards_range_and_sign() {
        assert_eq!(Value::parse("7").unwrap().as_u64(), Some(7));
        assert_eq!(Value::parse("-1").unwrap().as_u64(), None);
        assert_eq!(Value::parse("1.5").unwrap().as_u64(), None);
        assert_eq!(Value::parse("\"7\"").unwrap().as_u64(), None);
    }
}
