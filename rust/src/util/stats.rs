//! Small statistics toolbox used by characterisation benches (Fig. 15),
//! the robustness studies (Figs. 17/18) and the bench harness.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn var(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    var(xs).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Median absolute deviation (robust spread, used by the bench harness).
pub fn mad(xs: &[f64]) -> f64 {
    let m = median(xs);
    let devs: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&devs)
}

/// Equal-width histogram over `[lo, hi]`; returns (bin centers, counts).
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> (Vec<f64>, Vec<usize>) {
    assert!(bins > 0 && hi > lo);
    let width = (hi - lo) / bins as f64;
    let mut counts = vec![0usize; bins];
    for &x in xs {
        if x < lo || x > hi {
            continue;
        }
        let mut b = ((x - lo) / width) as usize;
        if b >= bins {
            b = bins - 1;
        }
        counts[b] += 1;
    }
    let centers = (0..bins)
        .map(|i| lo + (i as f64 + 0.5) * width)
        .collect();
    (centers, counts)
}

/// Fit a Gaussian to data by moments; returns (mu, sigma).
///
/// Used on `ln(w)` to recover the fabricated sigma_VT from measured
/// weights, reproducing the Fig. 15(c) "sigma_dVT ~ 16 mV" extraction.
pub fn fit_gaussian(xs: &[f64]) -> (f64, f64) {
    (mean(xs), std(xs))
}

/// Root-mean-square error between two equal-length series.
pub fn rmse(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let se: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    (se / a.len() as f64).sqrt()
}

/// Maximum relative spread `(max-min)/mid` in percent — the Fig. 17 metric
/// ("maximum variation of 22.7%" across VDD corners).
pub fn max_rel_spread_pct(xs: &[f64]) -> f64 {
    let max = xs.iter().cloned().fold(f64::MIN, f64::max);
    let min = xs.iter().cloned().fold(f64::MAX, f64::min);
    let mid = 0.5 * (max + min);
    if mid == 0.0 {
        0.0
    } else {
        (max - min) / mid * 100.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_median() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts_everything_in_range() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64 / 100.0).collect();
        let (centers, counts) = histogram(&xs, 0.0, 1.0, 10);
        assert_eq!(centers.len(), 10);
        assert_eq!(counts.iter().sum::<usize>(), 100);
        // float edge effects can move a boundary sample by one bin
        assert!(counts.iter().all(|&c| (9..=11).contains(&c)), "{counts:?}");
    }

    #[test]
    fn gaussian_fit_recovers_parameters() {
        let mut p = crate::util::prng::Prng::new(17);
        let xs: Vec<f64> = (0..100_000).map(|_| p.normal(3.0, 0.5)).collect();
        let (mu, sigma) = fit_gaussian(&xs);
        assert!((mu - 3.0).abs() < 0.01);
        assert!((sigma - 0.5).abs() < 0.01);
    }

    #[test]
    fn spread_metric() {
        let xs = [90.0, 100.0, 110.0];
        assert!((max_rel_spread_pct(&xs) - 20.0).abs() < 1e-9);
    }
}
