//! Client SDK for the typed serving protocol (DESIGN.md §15).
//!
//! One [`Client`] type, three transports behind it:
//!
//!   * [`Client::connect`] — TCP speaking protocol **v1** (binary
//!     frames; `predict_batch` is one round-trip for the whole batch);
//!   * [`Client::connect_v0`] — TCP speaking protocol **v0** (the
//!     ASCII line grammar; `predict_batch` degrades to one round-trip
//!     per row because v0 has no batch frame);
//!   * [`Client::in_process`] — no sockets at all: requests dispatch
//!     straight into `Coordinator::handle`, the same entry point the
//!     TCP front end uses, so in-process and wire callers provably
//!     share one code path.
//!
//! The CLI (`velm client`), the examples and the integration tests all
//! talk to the fleet through this type instead of hand-rolling socket
//! strings.

use std::io::BufReader;
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::Coordinator;
use crate::protocol::{
    Codec, FrameCodec, LineCodec, PredictRow, Prediction, Request, Response, StatsSnapshot,
    TimelineEvent, TraceEntry,
};

/// A handle on one serving fleet, over TCP (v0 or v1) or in-process.
pub struct Client {
    transport: Transport,
}

enum Transport {
    Wire {
        reader: BufReader<TcpStream>,
        writer: TcpStream,
        codec: Box<dyn Codec>,
    },
    Local(Arc<Coordinator>),
}

impl Client {
    /// Connect over TCP speaking protocol v1 (framed, batch-capable).
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Client> {
        Client::connect_with(addr, Box::new(FrameCodec))
    }

    /// Connect over TCP speaking protocol v0 (the ASCII line grammar) —
    /// for talking to pre-protocol servers, and for tests that pin the
    /// two wire formats against each other.
    pub fn connect_v0<A: ToSocketAddrs>(addr: A) -> Result<Client> {
        Client::connect_with(addr, Box::new(LineCodec))
    }

    fn connect_with<A: ToSocketAddrs>(addr: A, codec: Box<dyn Codec>) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connecting to the serving fleet")?;
        let _ = stream.set_nodelay(true); // request/response pattern: defeat Nagle
        let writer = stream.try_clone().context("cloning the client stream")?;
        Ok(Client {
            transport: Transport::Wire { reader: BufReader::new(stream), writer, codec },
        })
    }

    /// Wrap an in-process coordinator — same typed dispatch, no sockets.
    pub fn in_process(coord: Arc<Coordinator>) -> Client {
        Client { transport: Transport::Local(coord) }
    }

    /// Wire protocol version: `Some(0)` / `Some(1)` over TCP, `None`
    /// in-process (no wire involved).
    pub fn wire_version(&self) -> Option<u8> {
        match &self.transport {
            Transport::Wire { codec, .. } => Some(codec.version()),
            Transport::Local(_) => None,
        }
    }

    /// One request/response exchange through whatever transport this
    /// client wraps. All typed verbs below go through here.
    pub fn call(&mut self, req: Request) -> Result<Response> {
        match &mut self.transport {
            Transport::Local(coord) => Ok(coord.handle(req)),
            Transport::Wire { reader, writer, codec } => {
                codec.write_request(writer, &req).context("sending the request")?;
                codec
                    .read_response(reader, &req)
                    .context("reading the reply")?
                    .context("server closed the connection")
            }
        }
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> Result<()> {
        match self.call(Request::Ping)? {
            Response::Pong => Ok(()),
            other => Err(unexpected(other)),
        }
    }

    /// Score one row through one tenant's head (`None` = default head).
    pub fn predict(&mut self, tenant: Option<&str>, features: &[f64]) -> Result<Prediction> {
        let req = Request::Predict {
            tenant: tenant.map(str::to_string),
            features: features.to_vec(),
        };
        match self.call(req)? {
            Response::Predict(p) => Ok(p),
            other => Err(unexpected(other)),
        }
    }

    /// Score many rows, each addressed to its own tenant, preserving
    /// row order. Over v1 and in-process this is ONE submission into
    /// the batcher (one batch window, rows fanned to dies by the
    /// router); over v0 it falls back to one round-trip per row.
    /// An empty batch is refused on every transport (the v0 fallback
    /// would otherwise vacuously succeed where v1 errors).
    pub fn predict_batch(&mut self, rows: &[PredictRow]) -> Result<Vec<Prediction>> {
        anyhow::ensure!(!rows.is_empty(), "empty batch");
        if self.wire_version() == Some(0) {
            return rows
                .iter()
                .map(|row| self.predict(row.tenant.as_deref(), &row.features))
                .collect();
        }
        match self.call(Request::BatchPredict { rows: rows.to_vec() })? {
            Response::Batch(ps) => Ok(ps),
            other => Err(unexpected(other)),
        }
    }

    /// Train + install a tenant fleet-wide from a named dataset.
    /// Returns (task rendering, mean train score across dies).
    pub fn register(&mut self, name: &str, dataset: &str, seed: u64) -> Result<(String, f64)> {
        let req = Request::Register {
            name: name.to_string(),
            dataset: dataset.to_string(),
            seed,
        };
        match self.call(req)? {
            Response::Registered { task, score, .. } => Ok((task, score)),
            other => Err(unexpected(other)),
        }
    }

    /// Drop a tenant fleet-wide.
    pub fn unregister(&mut self, name: &str) -> Result<()> {
        match self.call(Request::Unregister { name: name.to_string() })? {
            Response::Unregistered { .. } => Ok(()),
            other => Err(unexpected(other)),
        }
    }

    /// One-line metrics snapshot.
    pub fn stats(&mut self) -> Result<String> {
        match self.call(Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => Err(unexpected(other)),
        }
    }

    /// Per-die lifecycle gauges + fleet counters.
    pub fn health(&mut self) -> Result<String> {
        match self.call(Request::Health)? {
            Response::Health(s) => Ok(s),
            other => Err(unexpected(other)),
        }
    }

    /// Governor status one-liner (DESIGN.md §17): enabled/disabled,
    /// per-die operating points, move counters, energy saved. Works on
    /// both wire versions (the v0 spelling is `GOVERNOR`).
    pub fn governor(&mut self) -> Result<String> {
        match self.call(Request::Governor)? {
            Response::Governor(s) => Ok(s),
            other => Err(unexpected(other)),
        }
    }

    /// Tenant directory one-liner.
    pub fn models(&mut self) -> Result<String> {
        match self.call(Request::Models)? {
            Response::Models(s) => Ok(s),
            other => Err(unexpected(other)),
        }
    }

    /// Pull a die from rotation for recalibration.
    pub fn drain(&mut self, die: usize) -> Result<()> {
        match self.call(Request::Drain { die })? {
            Response::Draining { .. } => Ok(()),
            other => Err(unexpected(other)),
        }
    }

    /// Dump the newest `last` flight-recorder entries (DESIGN.md §16),
    /// newest first. Typed traces need v1 or in-process; over v0 the
    /// `TRACE` verb is display-only and this returns the server's
    /// guidance as an error.
    pub fn trace(&mut self, last: usize) -> Result<Vec<TraceEntry>> {
        anyhow::ensure!(
            self.wire_version() != Some(0),
            "typed traces need the v1 framed protocol (v0 TRACE is display-only)"
        );
        match self.call(Request::Trace { last })? {
            Response::Trace(ts) => Ok(ts),
            other => Err(unexpected(other)),
        }
    }

    /// The newest `last` fleet timeline events (DESIGN.md §19), oldest
    /// first — the shape `coordinator::timeline::chrome_trace_json`
    /// renders for Perfetto. Needs v1 or in-process; v0 has no
    /// timeline frame.
    pub fn timeline(&mut self, last: usize) -> Result<Vec<TimelineEvent>> {
        anyhow::ensure!(
            self.wire_version() != Some(0),
            "timeline events need the v1 framed protocol (v0 has no timeline frame)"
        );
        match self.call(Request::Timeline { last })? {
            Response::Timeline(events) => Ok(events),
            other => Err(unexpected(other)),
        }
    }

    /// One consistent structured stats export (DESIGN.md §16). Needs
    /// v1 or in-process; v0 has no snapshot frame.
    pub fn snapshot(&mut self) -> Result<StatsSnapshot> {
        anyhow::ensure!(
            self.wire_version() != Some(0),
            "structured stats need the v1 framed protocol (use stats() on v0)"
        );
        match self.call(Request::Snapshot)? {
            Response::Snapshot(s) => Ok(s),
            other => Err(unexpected(other)),
        }
    }
}

impl Drop for Client {
    fn drop(&mut self) {
        // best-effort clean close so the server's connection thread
        // exits without waiting out its read timeout
        if let Transport::Wire { writer, codec, .. } = &mut self.transport {
            let _ = codec.write_quit(writer);
        }
    }
}

/// A reply of the wrong shape: a server-side `ERR` becomes the error
/// message; anything else names the unexpected variant.
fn unexpected(resp: Response) -> anyhow::Error {
    match resp {
        Response::Error(e) => anyhow::anyhow!("server error: {e}"),
        other => anyhow::anyhow!("unexpected reply {other:?}"),
    }
}
