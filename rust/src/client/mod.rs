//! Client SDK for the typed serving protocol (DESIGN.md §15).
//!
//! One [`Client`] type, three transports behind it:
//!
//!   * [`Client::connect`] — TCP speaking protocol **v1** (binary
//!     frames; `predict_batch` is one round-trip for the whole batch);
//!   * [`Client::connect_v0`] — TCP speaking protocol **v0** (the
//!     ASCII line grammar; `predict_batch` degrades to one round-trip
//!     per row because v0 has no batch frame);
//!   * [`Client::in_process`] — no sockets at all: requests dispatch
//!     straight into `Coordinator::handle`, the same entry point the
//!     TCP front end uses, so in-process and wire callers provably
//!     share one code path.
//!
//! The CLI (`velm client`), the examples and the integration tests all
//! talk to the fleet through this type instead of hand-rolling socket
//! strings.
//!
//! Since PR 10 (DESIGN.md §20) the v1 wire also carries **pipelined**
//! traffic: [`Client::send_pipelined`] fires a correlation-wrapped
//! request without waiting, [`Client::recv_pipelined`] collects
//! replies in completion order, and [`Client::predict_stream`] turns a
//! batch into row-by-row streamed replies as dies finish. Verbs a
//! transport cannot carry fail up front with a capability error naming
//! the required protocol — never a parse error.

use std::io::BufReader;
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::Coordinator;
use crate::protocol::frame;
use crate::protocol::{
    Codec, FrameCodec, LineCodec, PredictRow, Prediction, Request, Response, StatsSnapshot,
    TimelineEvent, TraceEntry,
};

/// A handle on one serving fleet, over TCP (v0 or v1) or in-process.
pub struct Client {
    transport: Transport,
    /// Next correlation id for pipelined/streamed v1 requests. Client-
    /// chosen; the server echoes it verbatim (DESIGN.md §20).
    next_corr: u64,
}

enum Transport {
    Wire {
        reader: BufReader<TcpStream>,
        writer: TcpStream,
        codec: Box<dyn Codec>,
    },
    Local(Arc<Coordinator>),
}

impl Client {
    /// Connect over TCP speaking protocol v1 (framed, batch-capable).
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Client> {
        Client::connect_with(addr, Box::new(FrameCodec))
    }

    /// Connect over TCP speaking protocol v0 (the ASCII line grammar) —
    /// for talking to pre-protocol servers, and for tests that pin the
    /// two wire formats against each other.
    pub fn connect_v0<A: ToSocketAddrs>(addr: A) -> Result<Client> {
        Client::connect_with(addr, Box::new(LineCodec))
    }

    fn connect_with<A: ToSocketAddrs>(addr: A, codec: Box<dyn Codec>) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connecting to the serving fleet")?;
        let _ = stream.set_nodelay(true); // request/response pattern: defeat Nagle
        let writer = stream.try_clone().context("cloning the client stream")?;
        Ok(Client {
            transport: Transport::Wire { reader: BufReader::new(stream), writer, codec },
            next_corr: 1,
        })
    }

    /// Wrap an in-process coordinator — same typed dispatch, no sockets.
    pub fn in_process(coord: Arc<Coordinator>) -> Client {
        Client { transport: Transport::Local(coord), next_corr: 1 }
    }

    /// Wire protocol version: `Some(0)` / `Some(1)` over TCP, `None`
    /// in-process (no wire involved).
    pub fn wire_version(&self) -> Option<u8> {
        match &self.transport {
            Transport::Wire { codec, .. } => Some(codec.version()),
            Transport::Local(_) => None,
        }
    }

    /// One request/response exchange through whatever transport this
    /// client wraps. All typed verbs below go through here.
    pub fn call(&mut self, req: Request) -> Result<Response> {
        match &mut self.transport {
            Transport::Local(coord) => Ok(coord.handle(req)),
            Transport::Wire { reader, writer, codec } => {
                codec.write_request(writer, &req).context("sending the request")?;
                codec
                    .read_response(reader, &req)
                    .context("reading the reply")?
                    .context("server closed the connection")
            }
        }
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> Result<()> {
        match self.call(Request::Ping)? {
            Response::Pong => Ok(()),
            other => Err(unexpected(other)),
        }
    }

    /// Score one row through one tenant's head (`None` = default head).
    pub fn predict(&mut self, tenant: Option<&str>, features: &[f64]) -> Result<Prediction> {
        let req = Request::Predict {
            tenant: tenant.map(str::to_string),
            features: features.to_vec(),
        };
        match self.call(req)? {
            Response::Predict(p) => Ok(p),
            other => Err(unexpected(other)),
        }
    }

    /// Score many rows, each addressed to its own tenant, preserving
    /// row order. Over v1 and in-process this is ONE submission into
    /// the batcher (one batch window, rows fanned to dies by the
    /// router); over v0 it falls back to one round-trip per row.
    /// An empty batch is refused on every transport (the v0 fallback
    /// would otherwise vacuously succeed where v1 errors).
    pub fn predict_batch(&mut self, rows: &[PredictRow]) -> Result<Vec<Prediction>> {
        self.predict_batch_with_progress(rows, |_, _| {})
    }

    /// [`Client::predict_batch`] with a progress callback: `on_row(i,
    /// prediction)` fires once per row. Over v0's row-per-round-trip
    /// degradation it fires as each round-trip lands — real progress
    /// through a long batch; over v1/in-process the reply is one unit,
    /// so the callback runs when it arrives (use
    /// [`Client::predict_stream`] for genuine streaming).
    pub fn predict_batch_with_progress(
        &mut self,
        rows: &[PredictRow],
        mut on_row: impl FnMut(usize, &Prediction),
    ) -> Result<Vec<Prediction>> {
        anyhow::ensure!(!rows.is_empty(), "empty batch");
        if self.wire_version() == Some(0) {
            let mut out = Vec::with_capacity(rows.len());
            for (i, row) in rows.iter().enumerate() {
                let p = self.predict(row.tenant.as_deref(), &row.features)?;
                on_row(i, &p);
                out.push(p);
            }
            return Ok(out);
        }
        match self.call(Request::BatchPredict { rows: rows.to_vec() })? {
            Response::Batch(ps) => {
                for (i, p) in ps.iter().enumerate() {
                    on_row(i, p);
                }
                Ok(ps)
            }
            other => Err(unexpected(other)),
        }
    }

    /// Streamed batch prediction (DESIGN.md §20): rows are answered as
    /// dies finish, `on_row(index, prediction)` firing in *completion*
    /// order; returns the reassembled row-order predictions plus the
    /// total conversion passes reported by the end-of-stream frame.
    /// Needs the v1 wire (correlation envelopes) or in-process; v0 has
    /// no stream frame.
    pub fn predict_stream(
        &mut self,
        rows: &[PredictRow],
        mut on_row: impl FnMut(usize, &Prediction),
    ) -> Result<(Vec<Prediction>, u64)> {
        anyhow::ensure!(!rows.is_empty(), "empty batch");
        anyhow::ensure!(
            self.wire_version() != Some(0),
            "streamed prediction needs the v1 framed protocol (v0 has no \
             stream frame; use predict_batch)"
        );
        let corr = self.next_corr;
        self.next_corr += 1;
        match &mut self.transport {
            Transport::Local(coord) => {
                // in-process: poll the per-row completion channels the
                // same way the reactor's worker does
                let rxs = coord.submit_batch(rows)?;
                let mut pending: Vec<Option<_>> = rxs.into_iter().map(Some).collect();
                let mut out: Vec<Option<Prediction>> = vec![None; rows.len()];
                let mut open = pending.len();
                let mut passes: u64 = 0;
                while open > 0 {
                    let mut moved = false;
                    for (i, slot) in pending.iter_mut().enumerate() {
                        let Some(rx) = slot else { continue };
                        match rx.try_recv() {
                            Ok(resp) => {
                                passes += resp.passes as u64;
                                let p = resp.to_prediction();
                                on_row(i, &p);
                                out[i] = Some(p);
                                *slot = None;
                                open -= 1;
                                moved = true;
                            }
                            Err(std::sync::mpsc::TryRecvError::Empty) => {}
                            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                                anyhow::bail!("batch row {i}: worker dropped the request");
                            }
                        }
                    }
                    if !moved {
                        std::thread::sleep(std::time::Duration::from_micros(200));
                    }
                }
                let preds = out
                    .into_iter()
                    .map(|p| p.expect("every open row resolved"))
                    .collect();
                Ok((preds, passes))
            }
            Transport::Wire { reader, writer, .. } => {
                let req = Request::BatchStream { rows: rows.to_vec() };
                let (ty, payload) = frame::encode_correlated_request(corr, &req);
                frame::write_frame(writer, ty, &payload).context("sending the stream request")?;
                let mut out: Vec<Option<Prediction>> = vec![None; rows.len()];
                let passes;
                loop {
                    let (ty, payload) = frame::read_frame(reader)
                        .context("reading a stream frame")?
                        .context("server closed the connection mid-stream")?;
                    match ty {
                        frame::R_STREAM_ROW => {
                            let (c, idx, p) = frame::decode_stream_row(&payload)
                                .map_err(|e| anyhow::anyhow!(e))?;
                            anyhow::ensure!(
                                c == corr,
                                "stream row for correlation id {c} (want {corr})"
                            );
                            let i = idx as usize;
                            anyhow::ensure!(i < out.len(), "stream row index {i} out of range");
                            on_row(i, &p);
                            out[i] = Some(p);
                        }
                        frame::R_STREAM_END => {
                            let (c, n, total) = frame::decode_stream_end(&payload)
                                .map_err(|e| anyhow::anyhow!(e))?;
                            anyhow::ensure!(
                                c == corr,
                                "stream end for correlation id {c} (want {corr})"
                            );
                            anyhow::ensure!(
                                n as usize == rows.len(),
                                "stream ended after {n} of {} rows",
                                rows.len()
                            );
                            passes = total;
                            break;
                        }
                        frame::R_CORR => {
                            let (c, resp) = frame::decode_correlated_response(&payload)
                                .map_err(|e| anyhow::anyhow!(e))?;
                            anyhow::ensure!(
                                c == corr,
                                "reply for correlation id {c} (want {corr})"
                            );
                            return Err(unexpected(resp));
                        }
                        other => anyhow::bail!("unexpected frame 0x{other:02X} mid-stream"),
                    }
                }
                let preds = out
                    .into_iter()
                    .enumerate()
                    .map(|(i, p)| p.with_context(|| format!("row {i} missing from the stream")))
                    .collect::<Result<Vec<_>>>()?;
                Ok((preds, passes))
            }
        }
    }

    /// Fire one correlation-wrapped request without waiting for the
    /// reply (v1 wire only) — many may be in flight on one connection.
    /// Returns the id to match against [`Client::recv_pipelined`].
    pub fn send_pipelined(&mut self, req: &Request) -> Result<u64> {
        anyhow::ensure!(
            self.wire_version() == Some(1),
            "pipelined requests need the v1 framed protocol"
        );
        let corr = self.next_corr;
        self.next_corr += 1;
        match &mut self.transport {
            Transport::Wire { writer, .. } => {
                let (ty, payload) = frame::encode_correlated_request(corr, req);
                frame::write_frame(writer, ty, &payload)
                    .context("sending the pipelined request")?;
                Ok(corr)
            }
            Transport::Local(_) => unreachable!("gated on wire_version above"),
        }
    }

    /// Collect the next pipelined reply (v1 wire only). Replies arrive
    /// in *completion* order, not send order — match by the echoed id.
    pub fn recv_pipelined(&mut self) -> Result<(u64, Response)> {
        anyhow::ensure!(
            self.wire_version() == Some(1),
            "pipelined requests need the v1 framed protocol"
        );
        match &mut self.transport {
            Transport::Wire { reader, .. } => {
                let (ty, payload) = frame::read_frame(reader)
                    .context("reading a pipelined reply")?
                    .context("server closed the connection")?;
                anyhow::ensure!(
                    ty == frame::R_CORR,
                    "expected a correlated reply, got frame 0x{ty:02X}"
                );
                let (corr, resp) =
                    frame::decode_correlated_response(&payload).map_err(|e| anyhow::anyhow!(e))?;
                Ok((corr, resp))
            }
            Transport::Local(_) => unreachable!("gated on wire_version above"),
        }
    }

    /// HELLO handshake (DESIGN.md §20): present `token`, bind the
    /// connection to the tenant scope it grants. Returns the granted
    /// scope (`["*"]` = unrestricted). Needs v1 or in-process; v0 has
    /// no hello frame.
    pub fn hello(&mut self, token: &str) -> Result<Vec<String>> {
        anyhow::ensure!(
            self.wire_version() != Some(0),
            "the HELLO handshake needs the v1 framed protocol (v0 has no hello frame)"
        );
        match self.call(Request::Hello { token: token.to_string() })? {
            Response::HelloOk { tenants } => Ok(tenants),
            other => Err(unexpected(other)),
        }
    }

    /// Stream one labelled OS-ELM row into a registered tenant's heads
    /// (shared-P update, DESIGN.md §14/§20). `targets` carries one
    /// value per head. Needs v1 or in-process; v0 has no tenant-update
    /// frame.
    pub fn tenant_update(&mut self, name: &str, features: &[f64], targets: &[f64]) -> Result<()> {
        anyhow::ensure!(
            self.wire_version() != Some(0),
            "live tenant updates need the v1 framed protocol (v0 has no tenant-update frame)"
        );
        let req = Request::TenantUpdate {
            name: name.to_string(),
            features: features.to_vec(),
            targets: targets.to_vec(),
        };
        match self.call(req)? {
            Response::Updated { .. } => Ok(()),
            other => Err(unexpected(other)),
        }
    }

    /// Train + install a tenant fleet-wide from a named dataset.
    /// Returns (task rendering, mean train score across dies).
    pub fn register(&mut self, name: &str, dataset: &str, seed: u64) -> Result<(String, f64)> {
        let req = Request::Register {
            name: name.to_string(),
            dataset: dataset.to_string(),
            seed,
        };
        match self.call(req)? {
            Response::Registered { task, score, .. } => Ok((task, score)),
            other => Err(unexpected(other)),
        }
    }

    /// Drop a tenant fleet-wide.
    pub fn unregister(&mut self, name: &str) -> Result<()> {
        match self.call(Request::Unregister { name: name.to_string() })? {
            Response::Unregistered { .. } => Ok(()),
            other => Err(unexpected(other)),
        }
    }

    /// One-line metrics snapshot.
    pub fn stats(&mut self) -> Result<String> {
        match self.call(Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => Err(unexpected(other)),
        }
    }

    /// Per-die lifecycle gauges + fleet counters.
    pub fn health(&mut self) -> Result<String> {
        match self.call(Request::Health)? {
            Response::Health(s) => Ok(s),
            other => Err(unexpected(other)),
        }
    }

    /// Governor status one-liner (DESIGN.md §17): enabled/disabled,
    /// per-die operating points, move counters, energy saved. Works on
    /// both wire versions (the v0 spelling is `GOVERNOR`).
    pub fn governor(&mut self) -> Result<String> {
        match self.call(Request::Governor)? {
            Response::Governor(s) => Ok(s),
            other => Err(unexpected(other)),
        }
    }

    /// Tenant directory one-liner.
    pub fn models(&mut self) -> Result<String> {
        match self.call(Request::Models)? {
            Response::Models(s) => Ok(s),
            other => Err(unexpected(other)),
        }
    }

    /// Pull a die from rotation for recalibration.
    pub fn drain(&mut self, die: usize) -> Result<()> {
        match self.call(Request::Drain { die })? {
            Response::Draining { .. } => Ok(()),
            other => Err(unexpected(other)),
        }
    }

    /// Dump the newest `last` flight-recorder entries (DESIGN.md §16),
    /// newest first. Typed traces need v1 or in-process; over v0 the
    /// `TRACE` verb is display-only and this returns the server's
    /// guidance as an error.
    pub fn trace(&mut self, last: usize) -> Result<Vec<TraceEntry>> {
        anyhow::ensure!(
            self.wire_version() != Some(0),
            "typed traces need the v1 framed protocol (v0 TRACE is display-only)"
        );
        match self.call(Request::Trace { last })? {
            Response::Trace(ts) => Ok(ts),
            other => Err(unexpected(other)),
        }
    }

    /// The newest `last` fleet timeline events (DESIGN.md §19), oldest
    /// first — the shape `coordinator::timeline::chrome_trace_json`
    /// renders for Perfetto. Needs v1 or in-process; v0 has no
    /// timeline frame.
    pub fn timeline(&mut self, last: usize) -> Result<Vec<TimelineEvent>> {
        anyhow::ensure!(
            self.wire_version() != Some(0),
            "timeline events need the v1 framed protocol (v0 has no timeline frame)"
        );
        match self.call(Request::Timeline { last })? {
            Response::Timeline(events) => Ok(events),
            other => Err(unexpected(other)),
        }
    }

    /// One consistent structured stats export (DESIGN.md §16). Needs
    /// v1 or in-process; v0 has no snapshot frame.
    pub fn snapshot(&mut self) -> Result<StatsSnapshot> {
        anyhow::ensure!(
            self.wire_version() != Some(0),
            "structured stats need the v1 framed protocol (use stats() on v0)"
        );
        match self.call(Request::Snapshot)? {
            Response::Snapshot(s) => Ok(s),
            other => Err(unexpected(other)),
        }
    }
}

impl Drop for Client {
    fn drop(&mut self) {
        // best-effort clean close so the server's connection thread
        // exits without waiting out its read timeout
        if let Transport::Wire { writer, codec, .. } = &mut self.transport {
            let _ = codec.write_quit(writer);
        }
    }
}

/// A reply of the wrong shape: a server-side `ERR` becomes the error
/// message; anything else names the unexpected variant.
fn unexpected(resp: Response) -> anyhow::Error {
    match resp {
        Response::Error(e) => anyhow::anyhow!("server error: {e}"),
        other => anyhow::anyhow!("unexpected reply {other:?}"),
    }
}
