//! Two-tier die recovery (DESIGN.md §12).
//!
//! **Tier 1 — renormalisation** (cheap, die stays in rotation): the
//! common-mode drift gain measured on the reference columns is cancelled
//! by reprogramming the counting window `T_neu` — the same knob the
//! paper adjusts between operating points (`ChipModel::program_t_neu`).
//! This restores the count *scale* into the counter's dynamic range
//! (un-saturating columns pushed over 2^b by a hot bias), which is what
//! the eq. 26 reference normalisation buys at system level.
//!
//! **Tier 2 — chip-in-the-loop head refit** (die drained first): when
//! the mismatch *profile* changed (aging, large temperature excursions
//! compressing eq. 12 weights through U_T), no common-mode correction
//! helps; the output weights are re-solved on the drifted die via the
//! OS-ELM path (`elm::online` RLS warm-started from a batch solve).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::chip::ChipModel;
use crate::elm::online::OnlineElm;
use crate::elm::secondstage::SecondStage;
use crate::extension::ServeChip;
use crate::registry::{fit_on_die, TenantEntry, TenantSpec};
use crate::util::mat::Mat;

/// Common-mode gain of `current` reference counts over the enrolment
/// `baseline` (total-count ratio). Clamped away from zero so a dead die
/// cannot produce an infinite correction.
pub fn common_mode_gain(baseline: &[f64], current: &[f64]) -> f64 {
    let b: f64 = baseline.iter().sum();
    let c: f64 = current.iter().sum();
    if b <= 0.0 {
        return 1.0;
    }
    (c / b).max(1e-6)
}

/// Mismatch-profile residual: relative RMS deviation of the reference
/// columns after removing the common-mode gain. Near zero for pure
/// VDD/temperature bias drift; grows when the per-mirror weights move
/// relative to each other (aging, U_T compression).
pub fn profile_residual(baseline: &[f64], current: &[f64]) -> f64 {
    let g = common_mode_gain(baseline, current);
    let mut acc = 0.0;
    let mut n = 0usize;
    for (&b, &c) in baseline.iter().zip(current) {
        if b > 1.0 {
            // near-dead columns carry quantisation noise, not signal
            let dev = c / g / b - 1.0;
            acc += dev * dev;
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        (acc / n as f64).sqrt()
    }
}

/// Tier-1 renormalisation: reprogram the counting window to cancel a
/// measured common-mode gain. The per-step correction is clamped to
/// [1/8, 8] so a pathological reading (dead die) cannot blow the window
/// up; escalation to tier 2 handles those. Returns the new `T_neu`.
pub fn renormalize(chip: &mut ChipModel, gain: f64) -> f64 {
    let correction = gain.clamp(1.0 / 8.0, 8.0);
    let t = chip.t_neu_set / correction;
    chip.program_t_neu(t);
    t
}

/// Tier-2 refit: re-solve the output weights chip-in-the-loop on the
/// drifted die. Assembles H exactly as the serving/training path does
/// (counter counts rescaled by 2^b, optional eq. 26 normalisation, the
/// rotation plan when the die serves virtually), warm-starts the OS-ELM
/// recursive solver on the first half and streams the second half
/// through RLS updates — the same machinery can keep absorbing labelled
/// traffic afterwards. Returns the refitted second stage ready to deploy.
pub fn refit_head(
    die: &mut ServeChip,
    normalize: bool,
    xs: &[Vec<f64>],
    ys: &[f64],
    lambda: f64,
    beta_bits: u32,
) -> Result<SecondStage, String> {
    if xs.is_empty() || xs.len() != ys.len() {
        return Err("refit needs a non-empty x/y set of equal length".into());
    }
    // H rows come from the exact serving/training assembly path
    // (`ServeChip::assemble_row`): rotation plan, counter-cap scaling
    // and eq. 26 normalisation included
    let rows: Vec<Vec<f64>> = xs
        .iter()
        .map(|x| {
            die.assemble_row(x, normalize)
                .map_err(|e| format!("refit forward: {e}"))
        })
        .collect::<Result<Vec<Vec<f64>>, String>>()?;
    let hmat = Mat::from_rows(&rows);
    let n0 = (hmat.rows / 2).max(1);
    let h0 = Mat::from_rows(&(0..n0).map(|i| hmat.row(i).to_vec()).collect::<Vec<_>>());
    let mut rls = OnlineElm::from_batch(&h0, &ys[..n0], lambda)?;
    for i in n0..hmat.rows {
        rls.update(hmat.row(i), ys[i]);
    }
    Ok(SecondStage::new(&rls.beta, beta_bits, normalize))
}

/// Tenant-aware tier-2 recovery (DESIGN.md §14): after the default head
/// refits, every registered tenant's heads re-solve chip-in-the-loop on
/// the same drifted die — each tenant costs one H assembly (its own
/// training set through the serving plan) and one shared Cholesky for
/// all of its heads, exactly like registration. The fresh entries
/// replace the stale ones wholesale, so the tenants' OS-ELM states are
/// also re-anchored to the drifted die. Returns the per-tenant
/// post-refit train scores. A failing tenant refit aborts with `Err`
/// (the manager then quarantines the die): a die that cannot solve a
/// registered model anymore must not keep serving it on stale weights.
pub fn refit_tenants(
    die: &mut ServeChip,
    normalize: bool,
    tenants: &mut BTreeMap<String, TenantEntry>,
) -> Result<Vec<(String, f64)>, String> {
    let specs: Vec<Arc<TenantSpec>> =
        tenants.values().map(|e| Arc::clone(&e.spec)).collect();
    let mut scores = Vec::with_capacity(specs.len());
    for spec in specs {
        let (entry, score) = fit_on_die(die, normalize, &spec)?;
        scores.push((spec.name.clone(), score));
        tenants.insert(spec.name.clone(), entry);
    }
    Ok(scores)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;
    use crate::elm::secondstage::{codes_sum, SecondStage};
    use crate::util::prng::Prng;

    #[test]
    fn gain_and_residual_decompose_drift_modes() {
        let base = vec![100.0, 200.0, 300.0, 400.0];
        // pure common-mode: every column up 20%
        let cm: Vec<f64> = base.iter().map(|v| v * 1.2).collect();
        assert!((common_mode_gain(&base, &cm) - 1.2).abs() < 1e-12);
        assert!(profile_residual(&base, &cm) < 1e-12);
        // profile change: columns move in opposite directions, same total
        let prof = vec![150.0, 150.0, 350.0, 350.0];
        assert!((common_mode_gain(&base, &prof) - 1.0).abs() < 1e-12);
        assert!(profile_residual(&base, &prof) > 0.1);
    }

    #[test]
    fn gain_is_clamped_for_dead_reference() {
        let base = vec![100.0, 100.0];
        assert!(common_mode_gain(&base, &[0.0, 0.0]) >= 1e-6);
        assert!((common_mode_gain(&[0.0, 0.0], &base) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn renormalize_rescales_the_window_and_clamps() {
        let cfg = ChipConfig::default().with_dims(8, 8).with_b(10);
        let mut chip = crate::chip::ChipModel::fabricate(cfg, 1);
        let t0 = chip.t_neu_set;
        let t1 = renormalize(&mut chip, 1.25);
        assert!((t1 - t0 / 1.25).abs() / t0 < 1e-12);
        assert!((chip.t_neu_set - t1).abs() < 1e-30);
        // pathological gain cannot blow the window past the clamp
        let t2 = renormalize(&mut chip, 1e-6);
        assert!((t2 - t1 * 8.0).abs() / t1 < 1e-9);
    }

    #[test]
    fn renormalize_restores_reference_counts_after_common_mode_drift() {
        // heat the die (PTAT bias gain up), renormalise by the measured
        // reference ratio, and the reference counts return near baseline
        let cfg = ChipConfig::default().with_dims(8, 24).with_b(10);
        let mut chip = crate::chip::ChipModel::fabricate(cfg, 2);
        let ref_codes = vec![(chip.cfg.code_fs() / 4) as u16; 8];
        let base: Vec<f64> = chip.forward(&ref_codes).iter().map(|&c| c as f64).collect();
        chip.set_temp(345.0);
        let hot: Vec<f64> = chip.forward(&ref_codes).iter().map(|&c| c as f64).collect();
        let g = common_mode_gain(&base, &hot);
        assert!(g > 1.05, "heating must raise the common mode, gain {g}");
        renormalize(&mut chip, g);
        let fixed: Vec<f64> = chip.forward(&ref_codes).iter().map(|&c| c as f64).collect();
        let g2 = common_mode_gain(&base, &fixed);
        assert!(
            (g2 - 1.0).abs() < (g - 1.0).abs() * 0.5,
            "renorm must cancel most of the gain: before {g}, after {g2}"
        );
    }

    fn labelled_blobs(d: usize, n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Prng::new(seed);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..n {
            let y = if rng.bool(0.5) { 1.0 } else { -1.0 };
            xs.push(
                (0..d)
                    .map(|_| (0.4 * y + rng.normal(0.0, 0.15)).clamp(-1.0, 1.0))
                    .collect::<Vec<f64>>(),
            );
            ys.push(y);
        }
        (xs, ys)
    }

    fn die_error(
        die: &mut ServeChip,
        s: &SecondStage,
        xs: &[Vec<f64>],
        ys: &[f64],
    ) -> f64 {
        let cfg = die.chip().cfg.clone();
        let mut wrong = 0usize;
        for (x, &y) in xs.iter().zip(ys) {
            let codes = crate::chip::dac::features_to_codes(x, &cfg);
            let h = die.forward(&codes).unwrap();
            let label = s.classify(&h, codes_sum(&codes), 0.0);
            if (label as f64 - y).abs() > 1e-9 {
                wrong += 1;
            }
        }
        wrong as f64 / xs.len() as f64
    }

    #[test]
    fn refit_recovers_accuracy_on_an_aged_die() {
        // train a head, age the mismatch so the head goes stale, refit
        // chip-in-the-loop and accuracy comes back
        let cfg = ChipConfig::default().with_dims(6, 48).with_b(10);
        let mut die = ServeChip::physical(crate::chip::ChipModel::fabricate(cfg, 3));
        let (xs, ys) = labelled_blobs(6, 160, 9);
        let second = refit_head(&mut die, false, &xs, &ys, 1e-2, 10).unwrap();
        let e0 = die_error(&mut die, &second, &xs, &ys);
        assert!(e0 < 0.1, "pre-drift err {e0}");
        die.chip_mut().age_mismatch(0.02, 55); // heavy profile change
        let e_stale = die_error(&mut die, &second, &xs, &ys);
        let refit = refit_head(&mut die, false, &xs, &ys, 1e-2, 10).unwrap();
        let e_refit = die_error(&mut die, &refit, &xs, &ys);
        assert!(
            e_refit < 0.1 && e_refit <= e_stale,
            "stale {e_stale} refit {e_refit}"
        );
    }

    #[test]
    fn refit_tenants_recovers_every_registered_head() {
        // two tenants (binary + regression) on one aged die: the tenant
        // refit must restore both, from each tenant's own training set
        let cfg = ChipConfig::default().with_dims(6, 48).with_b(10);
        let mut die = ServeChip::physical(crate::chip::ChipModel::fabricate(cfg, 7));
        let (xs, ys) = labelled_blobs(6, 150, 11);
        let reg_y: Vec<f64> = xs.iter().map(|x| 0.6 * x[0] - 0.4 * x[1]).collect();
        let cls = Arc::new(
            TenantSpec::classification("cls", xs.clone(), &ys, 1e-2, 10).unwrap(),
        );
        let reg =
            Arc::new(TenantSpec::regression("reg", xs.clone(), &reg_y, 1e-3, 10).unwrap());
        let mut tenants = BTreeMap::new();
        let (e0, cls_err0) = fit_on_die(&mut die, false, &cls).unwrap();
        tenants.insert("cls".to_string(), e0);
        let (e1, reg_rmse0) = fit_on_die(&mut die, false, &reg).unwrap();
        tenants.insert("reg".to_string(), e1);
        assert!(cls_err0 < 0.1 && reg_rmse0 < 0.15, "{cls_err0} {reg_rmse0}");
        die.chip_mut().age_mismatch(0.02, 77); // heavy profile change
        let scores = refit_tenants(&mut die, false, &mut tenants).unwrap();
        assert_eq!(scores.len(), 2);
        for (name, score) in &scores {
            let bound = if name.as_str() == "cls" { 0.12 } else { 0.2 };
            assert!(*score < bound, "tenant {name} not recovered: {score}");
        }
    }

    #[test]
    fn refit_works_through_the_rotation_plan_on_a_virtual_die() {
        // the drifted die serves a d=2k, L=2N virtual projection: the
        // chip-in-the-loop refit must assemble H through the rotation
        // plan and still recover the aged die
        let cfg = ChipConfig::default().with_dims(3, 24).with_b(10);
        let mut die =
            ServeChip::new(crate::chip::ChipModel::fabricate(cfg, 4), 6, 48).unwrap();
        assert_eq!(die.passes(), 4);
        let (xs, ys) = labelled_blobs(6, 160, 10);
        let second = refit_head(&mut die, false, &xs, &ys, 1e-2, 10).unwrap();
        let e0 = die_error(&mut die, &second, &xs, &ys);
        assert!(e0 < 0.12, "pre-drift err {e0}");
        die.chip_mut().age_mismatch(0.02, 56);
        let e_stale = die_error(&mut die, &second, &xs, &ys);
        let refit = refit_head(&mut die, false, &xs, &ys, 1e-2, 10).unwrap();
        let e_refit = die_error(&mut die, &refit, &xs, &ys);
        assert!(
            e_refit < 0.12 && e_refit <= e_stale,
            "stale {e_stale} refit {e_refit}"
        );
    }
}
