//! Per-die health probing (DESIGN.md §12): a pinned probe set classified
//! periodically on every die, plus a reference-column read — the serving
//! fleet's analogue of the paper's Fig. 17/18 monitoring. The reference
//! read drives the same common-mode mechanism eq. 26 exploits: the PTAT
//! bias / VDD residual scales every column together, so the ratio of
//! reference counts to their enrolment baseline measures common-mode
//! drift, while per-column deviations left after removing that gain
//! measure mismatch-profile change.

use std::collections::BTreeMap;

use crate::chip::dac;
use crate::config::ChipConfig;
use crate::elm::secondstage::{codes_sum, SecondStage};
use crate::extension::ServeChip;
use crate::registry::TenantEntry;
use crate::util::mat::Mat;

/// The pinned inputs every probe pass replays: labelled samples for the
/// probe error plus a fixed mid-scale reference vector for the
/// reference-column read.
#[derive(Clone, Debug)]
pub struct ProbeSet {
    /// Labelled probe samples (features in [-1, 1]^d).
    pub xs: Vec<Vec<f64>>,
    /// +-1 targets for the probe samples.
    pub ys: Vec<f64>,
    /// DAC codes of the reference read (one fixed code on every channel,
    /// low enough to sit in the neuron's monotone region pre-drift).
    pub ref_codes: Vec<u16>,
}

impl ProbeSet {
    /// Pin the first `n` training samples as the probe set and derive
    /// the reference read from the chip geometry (quarter full scale on
    /// every channel keeps the columns well below saturation at the
    /// nominal corner, so drift headroom is visible in both directions).
    /// The reference read spans the *served* input dimension (taken from
    /// the training rows), so it flows through the same rotation plan as
    /// traffic on a virtual die.
    pub fn from_training(xs: &[Vec<f64>], ys: &[f64], n: usize, cfg: &ChipConfig) -> Self {
        let n = n.min(xs.len()).min(ys.len());
        let ref_code = (cfg.code_fs() / 4) as u16;
        let d = xs.first().map_or(cfg.d, |x| x.len());
        ProbeSet {
            xs: xs[..n].to_vec(),
            ys: ys[..n].to_vec(),
            ref_codes: vec![ref_code; d],
        }
    }
}

/// One probe pass over one die: health telemetry the detector consumes.
#[derive(Clone, Debug)]
pub struct ProbeReport {
    /// Misclassification rate on the pinned probe set.
    pub err: f64,
    /// Reference-column counter outputs (as floats for the gain math).
    pub ref_counts: Vec<f64>,
    /// The counting window programmed at probe time [s] — renormalisation
    /// shows up here.
    pub t_neu: f64,
    /// Per-tenant probe scores (error rate / RMSE, the same metric as
    /// registration's train score), one per registered head on the die
    /// at probe time. Empty at enrolment — the baseline is probed
    /// before any tenant registers — so a tenant degrading later shows
    /// up as pure drift against the enrolled default-head baseline.
    pub tenant_errs: Vec<(String, f64)>,
}

impl ProbeReport {
    /// Mean reference count (the common-mode level).
    pub fn ref_mean(&self) -> f64 {
        if self.ref_counts.is_empty() {
            return 0.0;
        }
        self.ref_counts.iter().sum::<f64>() / self.ref_counts.len() as f64
    }

    /// Worst score across the default head and every tenant head — the
    /// figure the drift detector tracks, so a harder task degrading
    /// first (while the default head still probes clean) is caught.
    pub fn worst_err(&self) -> f64 {
        self.tenant_errs.iter().map(|(_, e)| *e).fold(self.err, f64::max)
    }
}

/// Run one probe pass: classify the pinned set through the die's own
/// second stage (exactly the serving path — the rotation plan included
/// when the die serves virtually), then read the reference columns.
/// Runs on the thread that owns the chip — the worker for live dies,
/// `Coordinator::start` for enrolment baselines. A probe whose shape no
/// longer matches the die counts as wrong / reads empty instead of
/// panicking, so a misconfigured probe degrades the die rather than
/// killing its worker.
pub fn run_probe(die: &mut ServeChip, second: &SecondStage, probe: &ProbeSet) -> ProbeReport {
    let cfg = die.chip().cfg.clone();
    let mut wrong = 0usize;
    for (x, &y) in probe.xs.iter().zip(&probe.ys) {
        let codes = dac::features_to_codes(x, &cfg);
        match die.forward(&codes) {
            Ok(h) => {
                let label = second.classify(&h, codes_sum(&codes), 0.0);
                if (label as f64 - y).abs() > 1e-9 {
                    wrong += 1;
                }
            }
            Err(_) => wrong += 1,
        }
    }
    let ref_counts: Vec<f64> = die
        .forward(&probe.ref_codes)
        .unwrap_or_default()
        .iter()
        .map(|&c| c as f64)
        .collect();
    ProbeReport {
        err: wrong as f64 / probe.xs.len().max(1) as f64,
        ref_counts,
        t_neu: die.chip().t_neu_set,
        tenant_errs: Vec::new(),
    }
}

/// Tenant-aware probe pass (DESIGN.md §14 registry-fairness gap): run
/// the default-head probe, then score every registered tenant's
/// *deployed* heads against a pinned prefix of its own training set —
/// at most the probe-set size per tenant, driven through the same
/// serving plan as traffic. No head is re-solved; this measures what
/// the installed models currently answer, so a harder task degrading
/// first raises [`ProbeReport::worst_err`] while the default head may
/// still probe clean. A tenant whose rows no longer assemble (shape
/// drift) scores worst-possible instead of panicking the worker.
pub fn run_probe_all(
    die: &mut ServeChip,
    second: &SecondStage,
    tenants: &BTreeMap<String, TenantEntry>,
    normalize: bool,
    probe: &ProbeSet,
) -> ProbeReport {
    let mut rep = run_probe(die, second, probe);
    let per_tenant = probe.xs.len().max(1);
    for (name, entry) in tenants {
        let n = entry.spec.xs.len().min(per_tenant);
        let rows: Result<Vec<Vec<f64>>, String> = entry.spec.xs[..n]
            .iter()
            .map(|x| die.assemble_row(x, normalize))
            .collect();
        let score = match rows {
            // score_predictions aligns targets by row index, so a
            // prefix of xs scores against the matching target prefix
            Ok(rows) if !rows.is_empty() => {
                entry.spec.score_predictions(&Mat::from_rows(&rows), &entry.rls)
            }
            _ => 1.0,
        };
        rep.tenant_errs.push((name.clone(), score));
    }
    rep
}

/// One environmental disturbance applied to the fleet at a given probe
/// tick — the drift-injection hook tests and benches use to replay the
/// Fig. 17 (VDD) and Fig. 18 (temperature) studies, plus the aging mode
/// (`age_sigma_vt`) that changes the mismatch *profile* rather than the
/// common mode.
#[derive(Clone, Debug)]
pub struct DriftEvent {
    /// Manager tick at which the event fires.
    pub at_tick: u64,
    /// Affected die, or `None` for the whole fleet.
    pub die: Option<usize>,
    /// New supply voltage [V], if any.
    pub vdd: Option<f64>,
    /// New die temperature [K], if any.
    pub temp_k: Option<f64>,
    /// Extra threshold-mismatch sigma [V] superimposed on the array.
    pub age_sigma_vt: Option<f64>,
}

/// A deterministic sequence of drift events keyed by probe tick.
#[derive(Clone, Debug, Default)]
pub struct DriftSchedule {
    pub events: Vec<DriftEvent>,
}

impl DriftSchedule {
    pub fn new() -> Self {
        DriftSchedule { events: Vec::new() }
    }

    /// Builder-style push.
    pub fn with(mut self, ev: DriftEvent) -> Self {
        self.events.push(ev);
        self
    }

    /// Fig. 18-style linear temperature ramp: `steps` events starting at
    /// `start_tick`, sweeping `t0` -> `t1` kelvin on `die` (None = all).
    pub fn temperature_ramp(die: Option<usize>, start_tick: u64, steps: u64, t0: f64, t1: f64) -> Self {
        let mut events = Vec::new();
        for k in 0..steps.max(1) {
            let frac = if steps <= 1 { 1.0 } else { k as f64 / (steps - 1) as f64 };
            events.push(DriftEvent {
                at_tick: start_tick + k,
                die,
                vdd: None,
                temp_k: Some(t0 + (t1 - t0) * frac),
                age_sigma_vt: None,
            });
        }
        DriftSchedule { events }
    }

    /// Events due at `tick`.
    pub fn due(&self, tick: u64) -> Vec<&DriftEvent> {
        self.events.iter().filter(|e| e.at_tick == tick).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::ChipModel;
    use crate::config::ChipConfig;

    fn die(seed: u64) -> (ServeChip, SecondStage, ProbeSet) {
        let cfg = ChipConfig::default().with_dims(8, 24).with_b(10);
        let mut chip = ChipModel::fabricate(cfg.clone(), seed);
        // a head trained on nothing still probes: beta all-ones
        let second = SecondStage::new(&[1.0; 24], 10, false);
        let xs: Vec<Vec<f64>> = (0..10)
            .map(|k| (0..8).map(|j| ((k + j) as f64 / 20.0) - 0.4).collect())
            .collect();
        let ys: Vec<f64> = (0..10).map(|k| if k % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let probe = ProbeSet::from_training(&xs, &ys, 8, &chip.cfg);
        let _ = chip.forward(&probe.ref_codes); // warm the cache path
        (ServeChip::physical(chip), second, probe)
    }

    #[test]
    fn probe_set_pins_first_n_and_ref_codes() {
        let cfg = ChipConfig::default().with_dims(5, 7);
        let xs = vec![vec![0.1; 5]; 20];
        let ys = vec![1.0; 20];
        let p = ProbeSet::from_training(&xs, &ys, 6, &cfg);
        assert_eq!(p.xs.len(), 6);
        assert_eq!(p.ys.len(), 6);
        assert_eq!(p.ref_codes, vec![(cfg.code_fs() / 4) as u16; 5]);
    }

    #[test]
    fn probe_is_deterministic_on_a_stable_die() {
        let (mut chip, second, probe) = die(3);
        let a = run_probe(&mut chip, &second, &probe);
        let b = run_probe(&mut chip, &second, &probe);
        assert_eq!(a.ref_counts, b.ref_counts);
        assert!((a.err - b.err).abs() < 1e-12);
        assert!(a.ref_mean() > 0.0, "reference columns must count");
    }

    #[test]
    fn probe_sees_temperature_drift_in_reference_counts() {
        let (mut chip, second, probe) = die(4);
        let cold = run_probe(&mut chip, &second, &probe);
        chip.chip_mut().set_temp(340.0);
        let hot = run_probe(&mut chip, &second, &probe);
        // PTAT bias gain raises the common-mode reference level
        assert!(
            hot.ref_mean() > cold.ref_mean() * 1.02,
            "hot {} vs cold {}",
            hot.ref_mean(),
            cold.ref_mean()
        );
    }

    #[test]
    fn probe_flows_through_the_rotation_plan_on_a_virtual_die() {
        // a 4x8 die serving a 12x24 virtual projection: probe samples
        // and reference read carry virtual dims, the report spans the
        // virtual hidden width, and the pass is deterministic
        let cfg = ChipConfig::default().with_dims(4, 8).with_b(10);
        let mk = || {
            ServeChip::new(ChipModel::fabricate(cfg.clone(), 31), 12, 24).unwrap()
        };
        let second = SecondStage::new(&[1.0; 24], 10, false);
        let xs: Vec<Vec<f64>> = (0..6)
            .map(|k| (0..12).map(|j| ((k + j) as f64 / 24.0) - 0.3).collect())
            .collect();
        let ys: Vec<f64> = (0..6).map(|k| if k % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let probe = ProbeSet::from_training(&xs, &ys, 6, &cfg);
        assert_eq!(probe.ref_codes.len(), 12, "reference read spans virtual d");
        let mut a = mk();
        let mut b = mk();
        let ra = run_probe(&mut a, &second, &probe);
        let rb = run_probe(&mut b, &second, &probe);
        assert_eq!(ra.ref_counts.len(), 24, "reference counts span virtual L");
        assert_eq!(ra.ref_counts, rb.ref_counts);
        assert!(ra.ref_mean() > 0.0);
    }

    #[test]
    fn tenant_aware_probe_scores_every_registered_head() {
        use crate::registry::{fit_on_die, TenantSpec};
        use std::sync::Arc;
        let (mut chip, second, probe) = die(5);
        // two tenants on the die: their deployed heads get scored
        let xs: Vec<Vec<f64>> =
            (0..12).map(|k| (0..8).map(|j| ((k * j) as f64 / 50.0) - 0.5).collect()).collect();
        let ys: Vec<f64> = (0..12).map(|k| (k as f64 / 12.0) - 0.5).collect();
        let mut tenants = BTreeMap::new();
        for name in ["alpha", "beta"] {
            let spec =
                Arc::new(TenantSpec::regression(name, xs.clone(), &ys, 1.0, 10).unwrap());
            let (entry, _) = fit_on_die(&mut chip, false, &spec).unwrap();
            tenants.insert(name.to_string(), entry);
        }
        let rep = run_probe_all(&mut chip, &second, &tenants, false, &probe);
        assert_eq!(rep.tenant_errs.len(), 2);
        assert_eq!(rep.tenant_errs[0].0, "alpha");
        assert_eq!(rep.tenant_errs[1].0, "beta");
        assert!(rep.tenant_errs.iter().all(|(_, e)| e.is_finite() && *e >= 0.0));
        assert!(rep.worst_err() >= rep.err, "worst_err covers the default head");
        // with no tenants the pass degenerates to the plain probe
        let plain = run_probe(&mut chip, &second, &probe);
        let none = run_probe_all(&mut chip, &second, &BTreeMap::new(), false, &probe);
        assert!(none.tenant_errs.is_empty());
        assert!((none.err - plain.err).abs() < 1e-12);
        assert!((none.worst_err() - none.err).abs() < 1e-12);
    }

    #[test]
    fn worst_err_takes_the_max_over_heads() {
        let rep = ProbeReport {
            err: 0.1,
            ref_counts: vec![],
            t_neu: 1e-6,
            tenant_errs: vec![("a".into(), 0.05), ("b".into(), 0.4)],
        };
        assert!((rep.worst_err() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn ramp_schedule_covers_all_ticks() {
        let s = DriftSchedule::temperature_ramp(Some(0), 2, 4, 300.0, 330.0);
        assert_eq!(s.events.len(), 4);
        assert!(s.due(0).is_empty());
        assert_eq!(s.due(2).len(), 1);
        assert_eq!(s.due(5).len(), 1);
        let last = s.due(5)[0];
        assert_eq!(last.temp_k, Some(330.0));
        let first = s.due(2)[0];
        assert_eq!(first.temp_k, Some(300.0));
    }
}
