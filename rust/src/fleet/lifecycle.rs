//! Die lifecycle management (DESIGN.md §12): the per-die state machine
//!
//! ```text
//! Healthy -> Degraded -> Draining -> Recalibrating -> Healthy
//!                                                  \-> Quarantined
//! ```
//!
//! plus `Standby` for hot spares promoted when a die is quarantined.
//! Only `Healthy` dies are routable; the shared [`FleetState`] is read
//! lock-free by `coordinator::Router` on every route decision.

use std::fmt;
use std::sync::{mpsc, Arc};
use std::time::Duration;

use crate::sync::{AtomicU8, Ordering};

use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{ControlMsg, WorkerMsg};
use crate::coordinator::router::Outstanding;

use super::detector::{DriftDetector, DriftVerdict};
use super::probe::{DriftSchedule, ProbeReport, ProbeSet};

/// Lifecycle state of one die.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DieState {
    /// In rotation, serving traffic.
    Healthy,
    /// Drift flagged; out of rotation pending confirmation.
    Degraded,
    /// Out of rotation, waiting for in-flight work to finish.
    Draining,
    /// Drained; chip-in-the-loop head refit in progress.
    Recalibrating,
    /// Recovery failed; permanently out of rotation.
    Quarantined,
    /// Trained hot spare, promoted when a die is quarantined.
    Standby,
}

impl DieState {
    fn to_u8(self) -> u8 {
        match self {
            DieState::Healthy => 0,
            DieState::Degraded => 1,
            DieState::Draining => 2,
            DieState::Recalibrating => 3,
            DieState::Quarantined => 4,
            DieState::Standby => 5,
        }
    }

    fn from_u8(v: u8) -> DieState {
        match v {
            0 => DieState::Healthy,
            1 => DieState::Degraded,
            2 => DieState::Draining,
            3 => DieState::Recalibrating,
            4 => DieState::Quarantined,
            _ => DieState::Standby,
        }
    }
}

impl fmt::Display for DieState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            DieState::Healthy => "Healthy",
            DieState::Degraded => "Degraded",
            DieState::Draining => "Draining",
            DieState::Recalibrating => "Recalibrating",
            DieState::Quarantined => "Quarantined",
            DieState::Standby => "Standby",
        };
        write!(f, "{s}")
    }
}

/// Shared per-die health gauges: written by the fleet manager, read
/// lock-free by the router on every route decision.
#[derive(Clone)]
pub struct FleetState(Arc<Vec<AtomicU8>>);

impl FleetState {
    /// `n` dies total; the first `n_active` start Healthy, the rest are
    /// hot standbys.
    pub fn new(n: usize, n_active: usize) -> Self {
        FleetState(Arc::new(
            (0..n)
                .map(|i| {
                    AtomicU8::new(if i < n_active {
                        DieState::Healthy.to_u8()
                    } else {
                        DieState::Standby.to_u8()
                    })
                })
                .collect(),
        ))
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn get(&self, i: usize) -> DieState {
        // relaxed-ok: single-byte state gauge; the router may act on a
        // stale state for one route decision, which the lifecycle
        // already tolerates (drains wait for outstanding work, and a
        // request routed to a just-degraded die is still answered).
        DieState::from_u8(self.0[i].load(Ordering::Relaxed))
    }

    pub fn set(&self, i: usize, s: DieState) {
        // relaxed-ok: see `get` — the value is self-contained; no
        // other memory is published through it.
        self.0[i].store(s.to_u8(), Ordering::Relaxed);
    }

    /// May the router send traffic to die `i`?
    pub fn routable(&self, i: usize) -> bool {
        self.get(i) == DieState::Healthy
    }

    pub fn snapshot(&self) -> Vec<DieState> {
        (0..self.len()).map(|i| self.get(i)).collect()
    }

    /// One-line per-die gauge readout: `die0=Healthy die1=Draining ...`.
    pub fn summary(&self) -> String {
        (0..self.len())
            .map(|i| format!("die{i}={}", self.get(i)))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Fleet-health settings carried by `config::SystemConfig`.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Training samples pinned into the probe set.
    pub probe_n: usize,
    /// Background probe cadence; `None` = probe only on explicit
    /// `Coordinator::fleet_tick` calls (tests, CLI).
    pub probe_period: Option<Duration>,
    /// EWMA smoothing factor for the drift detector (1.0 = no memory).
    pub ewma_alpha: f64,
    /// Smoothed probe-error increase over baseline flagged as drift.
    pub err_margin: f64,
    /// Smoothed common-mode reference shift that triggers tier-1
    /// renormalisation.
    pub cm_threshold: f64,
    /// Smoothed per-column reference residual that flags a
    /// mismatch-profile change (tier-2 refit).
    pub profile_threshold: f64,
    /// Renormalisation attempts (since last stable probe) before an
    /// uncured error escalates to the refit path anyway.
    pub max_renorms: u32,
    /// Post-refit probe error above which the die is quarantined
    /// instead of re-admitted.
    pub quarantine_err: f64,
    /// How long to wait for a worker's probe/calibration reply before
    /// counting a miss.
    pub reply_timeout: Duration,
    /// Consecutive unanswered probes before the die is declared dead
    /// and quarantined — a single slow reply (worker backlogged under
    /// load) only logs and retries next tick.
    pub max_probe_misses: u32,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            probe_n: 32,
            probe_period: None,
            ewma_alpha: 0.6,
            err_margin: 0.08,
            cm_threshold: 0.05,
            profile_threshold: 0.08,
            max_renorms: 2,
            quarantine_err: 0.35,
            reply_timeout: Duration::from_secs(5),
            max_probe_misses: 3,
        }
    }
}

/// One-line fleet status from the shared gauges and counters. Both
/// inputs are atomics, so this needs no manager lock — the TCP `HEALTH`
/// command stays responsive even while a tick is blocked on a slow
/// worker reply.
pub fn status_line(state: &FleetState, metrics: &Metrics) -> String {
    // relaxed-ok: independent monotone fleet counters; the line is a
    // diagnostic summary with no cross-counter invariant.
    format!(
        "{} probes={} renorms={} refits={} quarantines={} promotions={}",
        state.summary(),
        metrics.probes.load(Ordering::Relaxed),
        metrics.renorms.load(Ordering::Relaxed),
        metrics.refits.load(Ordering::Relaxed),
        metrics.quarantines.load(Ordering::Relaxed),
        metrics.promotions.load(Ordering::Relaxed),
    )
}

/// Everything the manager needs at construction (mirrors
/// `worker::WorkerSetup`).
pub struct FleetSetup {
    pub senders: Vec<mpsc::Sender<WorkerMsg>>,
    pub state: FleetState,
    pub outstanding: Outstanding,
    pub metrics: Arc<Metrics>,
    pub cfg: FleetConfig,
    pub probe: Arc<ProbeSet>,
    /// Enrolment baseline probe per die (captured at training time).
    pub baselines: Vec<ProbeReport>,
    /// Refit set (the training data) for tier-2 recovery.
    pub refit_x: Arc<Vec<Vec<f64>>>,
    pub refit_y: Arc<Vec<f64>>,
    pub lambda: f64,
    pub beta_bits: u32,
}

/// The fleet-health driver: probes dies, runs the drift detectors and
/// walks the per-die state machine, issuing renormalisation / refit
/// commands to the workers. Stepped by `tick()` — from the background
/// prober thread when a cadence is configured, or explicitly from tests
/// and the CLI.
pub struct FleetManager {
    senders: Vec<mpsc::Sender<WorkerMsg>>,
    pub state: FleetState,
    outstanding: Outstanding,
    metrics: Arc<Metrics>,
    cfg: FleetConfig,
    probe: Arc<ProbeSet>,
    detectors: Vec<DriftDetector>,
    renorm_tries: Vec<u32>,
    /// Consecutive unanswered probes per die (reset on any reply).
    probe_misses: Vec<u32>,
    refit_x: Arc<Vec<Vec<f64>>>,
    refit_y: Arc<Vec<f64>>,
    lambda: f64,
    beta_bits: u32,
    schedule: DriftSchedule,
    tick_no: u64,
    log: Vec<String>,
}

impl FleetManager {
    // relaxed-ok: the probes/renorms/refits/quarantines/promotions
    // counters booked below are independent monotone telemetry
    // (exported via Metrics::snapshot); no reader infers other memory
    // from their values.
    pub fn new(s: FleetSetup) -> Self {
        let detectors = s
            .baselines
            .iter()
            .map(|b| DriftDetector::new(b, &s.cfg))
            .collect();
        let n = s.senders.len();
        FleetManager {
            senders: s.senders,
            state: s.state,
            outstanding: s.outstanding,
            metrics: s.metrics,
            cfg: s.cfg,
            probe: s.probe,
            detectors,
            renorm_tries: vec![0; n],
            probe_misses: vec![0; n],
            refit_x: s.refit_x,
            refit_y: s.refit_y,
            lambda: s.lambda,
            beta_bits: s.beta_bits,
            schedule: DriftSchedule::new(),
            tick_no: 0,
            log: Vec::new(),
        }
    }

    /// Install a drift-injection schedule (tests / benches / examples).
    pub fn set_schedule(&mut self, s: DriftSchedule) {
        self.schedule = s;
    }

    /// Ticks completed so far.
    pub fn ticks(&self) -> u64 {
        self.tick_no
    }

    /// Human-readable event log (bounded).
    pub fn log(&self) -> &[String] {
        &self.log
    }

    /// One-line status: per-die gauges for the `HEALTH` command / CLI.
    pub fn status_line(&self) -> String {
        status_line(&self.state, &self.metrics)
    }

    fn note(&mut self, msg: String) {
        if self.log.len() >= 256 {
            self.log.remove(0);
        }
        self.log.push(msg);
    }

    /// Apply a drift event to one die or the whole fleet (the L1
    /// injection hook: VDD / temperature / mismatch aging).
    pub fn inject(
        &mut self,
        die: Option<usize>,
        vdd: Option<f64>,
        temp_k: Option<f64>,
        age_sigma_vt: Option<f64>,
    ) {
        let targets: Vec<usize> = match die {
            Some(i) if i < self.senders.len() => vec![i],
            Some(i) => {
                // loudly refuse rather than silently measuring an
                // un-drifted fleet in a drift experiment
                let t = self.tick_no;
                let n = self.senders.len();
                self.note(format!(
                    "tick {t}: drift injection IGNORED: no die {i} (fleet has {n})"
                ));
                vec![]
            }
            None => (0..self.senders.len()).collect(),
        };
        for i in targets {
            let _ = self.senders[i].send(WorkerMsg::Control(ControlMsg::SetEnv {
                vdd,
                temp_k,
                age_sigma_vt,
                seed: 0xD21F7 ^ (i as u64) ^ self.tick_no.wrapping_mul(0x9E37),
            }));
        }
    }

    /// Operator-initiated drain (the server's `DRAIN <die>` command):
    /// pull the die from rotation; the next ticks walk it through
    /// Draining -> Recalibrating -> Healthy | Quarantined.
    pub fn drain(&mut self, die: usize) -> Result<(), String> {
        if die >= self.state.len() {
            return Err(format!("no such die {die} (fleet has {})", self.state.len()));
        }
        match self.state.get(die) {
            DieState::Healthy | DieState::Degraded => {
                self.state.set(die, DieState::Draining);
                let t = self.tick_no;
                self.note(format!("tick {t}: die {die} draining (operator request)"));
                Ok(())
            }
            s => Err(format!("die {die} is {s}, not drainable")),
        }
    }

    /// Synchronous probe of one die through its worker thread.
    fn probe_die(&self, die: usize) -> Result<ProbeReport, String> {
        let (tx, rx) = mpsc::channel();
        self.senders[die]
            .send(WorkerMsg::Control(ControlMsg::Probe {
                probe: Arc::clone(&self.probe),
                reply: tx,
            }))
            .map_err(|_| format!("worker {die} is gone"))?;
        self.metrics.probes.fetch_add(1, Ordering::Relaxed);
        rx.recv_timeout(self.cfg.reply_timeout)
            .map_err(|_| format!("worker {die} did not answer the probe"))
    }

    /// Tier-1: tell the worker to cancel a measured common-mode gain by
    /// reprogramming its counting window; waits for the acknowledgement
    /// so a following probe observes the corrected die.
    fn renormalize_die(&self, die: usize, gain: f64) -> Result<f64, String> {
        let (tx, rx) = mpsc::channel();
        self.senders[die]
            .send(WorkerMsg::Control(ControlMsg::Renormalize { gain, reply: tx }))
            .map_err(|_| format!("worker {die} is gone"))?;
        rx.recv_timeout(self.cfg.reply_timeout)
            .map_err(|_| format!("worker {die} did not acknowledge renormalisation"))
    }

    /// Tier-2: chip-in-the-loop head refit on the (drained) die; the
    /// worker replies with a post-refit probe report plus the
    /// per-tenant post-refit train scores (DESIGN.md §14).
    fn refit_die(&self, die: usize) -> Result<(ProbeReport, Vec<(String, f64)>), String> {
        let (tx, rx) = mpsc::channel();
        self.senders[die]
            .send(WorkerMsg::Control(ControlMsg::Refit {
                xs: Arc::clone(&self.refit_x),
                ys: Arc::clone(&self.refit_y),
                lambda: self.lambda,
                beta_bits: self.beta_bits,
                probe: Arc::clone(&self.probe),
                reply: tx,
            }))
            .map_err(|_| format!("worker {die} is gone"))?;
        rx.recv_timeout(self.cfg.reply_timeout)
            .map_err(|_| format!("worker {die} did not answer the refit"))?
    }

    /// Quarantine a die and promote the first available standby.
    fn quarantine(&mut self, die: usize, why: String) {
        self.state.set(die, DieState::Quarantined);
        self.metrics.quarantines.fetch_add(1, Ordering::Relaxed);
        let t = self.tick_no;
        self.note(format!("tick {t}: die {die} QUARANTINED: {why}"));
        if let Some(spare) = (0..self.state.len()).find(|&i| self.state.get(i) == DieState::Standby)
        {
            self.state.set(spare, DieState::Healthy);
            self.metrics.promotions.fetch_add(1, Ordering::Relaxed);
            self.note(format!("tick {t}: standby die {spare} promoted to Healthy"));
        }
    }

    /// One probe/recovery pass over the whole fleet.
    pub fn tick(&mut self) {
        // 1. due drift injections (tests/benches replaying Figs. 17/18)
        let due: Vec<super::probe::DriftEvent> =
            self.schedule.due(self.tick_no).into_iter().cloned().collect();
        for ev in due {
            self.inject(ev.die, ev.vdd, ev.temp_k, ev.age_sigma_vt);
        }
        // 2. per-die state machine
        for die in 0..self.senders.len() {
            match self.state.get(die) {
                DieState::Standby | DieState::Quarantined => {}
                DieState::Healthy | DieState::Degraded => self.step_monitored(die),
                DieState::Draining => {
                    if self.outstanding.load(die) == 0 {
                        self.state.set(die, DieState::Recalibrating);
                        let t = self.tick_no;
                        self.note(format!("tick {t}: die {die} drained, recalibrating"));
                    }
                }
                DieState::Recalibrating => self.step_recalibrate(die),
            }
        }
        self.tick_no += 1;
    }

    /// Probe a monitored (Healthy/Degraded) die and act on the verdict.
    fn step_monitored(&mut self, die: usize) {
        let was = self.state.get(die);
        let rep = match self.probe_die(die) {
            Ok(r) => {
                self.probe_misses[die] = 0;
                r
            }
            Err(e) => {
                // a backlogged worker answers late, a dead one never
                // does: tolerate a few misses before giving up on it
                self.probe_misses[die] += 1;
                let misses = self.probe_misses[die];
                let limit = self.cfg.max_probe_misses;
                if misses >= limit {
                    self.quarantine(die, format!("{e} ({misses} consecutive misses)"));
                } else {
                    let t = self.tick_no;
                    self.note(format!(
                        "tick {t}: die {die} probe unanswered ({misses}/{limit}), retrying"
                    ));
                }
                return;
            }
        };
        let obs = self.detectors[die].update(&rep);
        let t = self.tick_no;
        match obs.verdict {
            DriftVerdict::Stable => {
                self.renorm_tries[die] = 0;
                if was == DieState::Degraded {
                    // transient: telemetry recovered before the drain
                    self.state.set(die, DieState::Healthy);
                    self.note(format!("tick {t}: die {die} re-admitted (drift cleared)"));
                }
            }
            DriftVerdict::CommonMode => {
                let escalate = self.renorm_tries[die] >= self.cfg.max_renorms
                    && self.detectors[die].err_excess() > self.cfg.err_margin;
                if escalate {
                    // renormalisation is not curing it: treat as profile
                    self.degrade(die, was, format!("renorm x{} ineffective", self.renorm_tries[die]));
                } else {
                    // tier 1: cancel the gain, die stays in rotation
                    match self.renormalize_die(die, obs.gain) {
                        Ok(t_neu) => {
                            self.renorm_tries[die] += 1;
                            self.detectors[die].note_renormalized();
                            self.metrics.renorms.fetch_add(1, Ordering::Relaxed);
                            self.note(format!(
                                "tick {t}: die {die} renormalised (gain {:.3}, T_neu {:.2} us)",
                                obs.gain,
                                t_neu * 1e6
                            ));
                        }
                        Err(e) => self.quarantine(die, e),
                    }
                }
            }
            DriftVerdict::Profile => {
                self.degrade(
                    die,
                    was,
                    format!("profile residual {:.3}, err {:.3}", obs.residual, obs.err),
                );
            }
        }
    }

    /// Profile-drift path: Healthy -> Degraded (confirm next tick),
    /// Degraded -> Draining (pull from rotation).
    fn degrade(&mut self, die: usize, was: DieState, why: String) {
        let t = self.tick_no;
        match was {
            DieState::Healthy => {
                self.state.set(die, DieState::Degraded);
                self.note(format!("tick {t}: die {die} degraded: {why}"));
            }
            _ => {
                self.state.set(die, DieState::Draining);
                self.note(format!("tick {t}: die {die} draining: {why}"));
            }
        }
    }

    /// Refit a drained die and re-admit or quarantine it.
    fn step_recalibrate(&mut self, die: usize) {
        let t = self.tick_no;
        match self.refit_die(die) {
            Ok((rep, tenant_scores)) if rep.err <= self.cfg.quarantine_err => {
                self.detectors[die] = DriftDetector::new(&rep, &self.cfg);
                self.renorm_tries[die] = 0;
                self.probe_misses[die] = 0;
                self.state.set(die, DieState::Healthy);
                self.metrics.refits.fetch_add(1, Ordering::Relaxed);
                // refresh the tenant gauges with this die's post-refit
                // scores (DESIGN.md §14) — MODELS/STATS must not keep
                // reporting registration-time numbers for re-solved
                // heads. Only existing gauges update: a tenant
                // unregistered mid-refit must not resurrect.
                for (name, score) in &tenant_scores {
                    if let Some(m) = self.metrics.tenant_handle(name) {
                        m.set_score(*score);
                    }
                }
                self.note(format!(
                    "tick {t}: die {die} recalibrated (probe err {:.3}, {} tenant \
                     heads re-solved), re-admitted",
                    rep.err,
                    tenant_scores.len()
                ));
            }
            Ok((rep, _)) => {
                self.quarantine(die, format!("post-refit probe err {:.3}", rep.err));
            }
            Err(e) => {
                self.quarantine(die, format!("refit failed: {e}"));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_roundtrips_through_u8() {
        for s in [
            DieState::Healthy,
            DieState::Degraded,
            DieState::Draining,
            DieState::Recalibrating,
            DieState::Quarantined,
            DieState::Standby,
        ] {
            assert_eq!(DieState::from_u8(s.to_u8()), s);
        }
    }

    #[test]
    fn fleet_state_routes_only_healthy() {
        let st = FleetState::new(3, 2);
        assert_eq!(st.len(), 3);
        assert!(st.routable(0) && st.routable(1));
        assert!(!st.routable(2), "standby must not be routable");
        st.set(1, DieState::Draining);
        assert!(!st.routable(1));
        st.set(1, DieState::Healthy);
        assert!(st.routable(1));
        assert_eq!(st.snapshot()[2], DieState::Standby);
        assert!(st.summary().contains("die0=Healthy"));
        assert!(st.summary().contains("die2=Standby"));
    }

    #[test]
    fn default_config_is_sane() {
        let c = FleetConfig::default();
        assert!(c.probe_n > 0);
        assert!(c.probe_period.is_none());
        assert!(c.ewma_alpha > 0.0 && c.ewma_alpha <= 1.0);
        assert!(c.cm_threshold > 0.0 && c.profile_threshold > 0.0);
        assert!(c.quarantine_err > c.err_margin);
    }
}
