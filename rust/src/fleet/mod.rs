//! Fleet health subsystem (DESIGN.md §12): drift telemetry, online
//! recalibration and die lifecycle management for the L3 serving fleet.
//!
//! The paper's Section VI / Figs. 17–18 show the analog array drifting
//! under VDD and temperature shifts; `chip` models exactly that (PTAT
//! bias gain, I_rst(VDD), U_T weight compression). This module closes
//! the loop in production: every die is periodically **probed**
//! ([`probe`]) with a pinned classification set plus a reference-column
//! read; a per-die **detector** ([`detector`]) separates common-mode
//! drift (cancellable, the eq. 26 mechanism) from mismatch-profile
//! change (not cancellable); two **calibration** tiers ([`calibrate`])
//! recover the die — cheap counting-window renormalisation in rotation,
//! or a drained chip-in-the-loop head refit through the OS-ELM RLS
//! path; and the **lifecycle** manager ([`lifecycle`]) walks each die
//! through `Healthy -> Degraded -> Draining -> Recalibrating ->
//! Healthy | Quarantined`, promoting hot standbys so capacity survives
//! quarantines. The router reads the shared [`FleetState`] lock-free
//! and only routes to `Healthy` dies.

pub mod calibrate;
pub mod detector;
pub mod lifecycle;
pub mod probe;

pub use detector::{DriftDetector, DriftObservation, DriftVerdict};
pub use lifecycle::{DieState, FleetConfig, FleetManager, FleetSetup, FleetState};
pub use probe::{DriftEvent, DriftSchedule, ProbeReport, ProbeSet};
