//! Drift detection (DESIGN.md §12): EWMA-smoothed thresholds over the
//! probe telemetry, separating the two drift modes the recovery tiers
//! address — common-mode reference shift (fixable by renormalisation,
//! the eq. 26 mechanism) versus mismatch-profile change or unexplained
//! probe-error growth (needs a chip-in-the-loop head refit).

use super::calibrate::{common_mode_gain, profile_residual};
use super::lifecycle::FleetConfig;
use super::probe::ProbeReport;

/// What the detector concluded from the latest probe.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriftVerdict {
    /// Telemetry within thresholds of the enrolment baseline.
    Stable,
    /// Reference columns moved together: renormalise (tier 1).
    CommonMode,
    /// Relative weights moved or accuracy fell without a common-mode
    /// explanation: drain and refit (tier 2).
    Profile,
}

/// The smoothed observation backing a verdict (for logs and escalation).
#[derive(Clone, Copy, Debug)]
pub struct DriftObservation {
    pub verdict: DriftVerdict,
    /// EWMA common-mode gain vs the baseline reference read.
    pub gain: f64,
    /// EWMA per-column residual after removing the gain.
    pub residual: f64,
    /// EWMA probe error.
    pub err: f64,
}

/// Per-die drift detector: enrolment baseline + EWMA state.
#[derive(Clone, Debug)]
pub struct DriftDetector {
    baseline_err: f64,
    baseline_ref: Vec<f64>,
    ewma_err: f64,
    ewma_gain: f64,
    ewma_residual: f64,
    alpha: f64,
    err_margin: f64,
    cm_threshold: f64,
    profile_threshold: f64,
}

impl DriftDetector {
    /// Start from an enrolment (or post-recalibration) baseline probe.
    pub fn new(baseline: &ProbeReport, cfg: &FleetConfig) -> Self {
        DriftDetector {
            baseline_err: baseline.worst_err(),
            baseline_ref: baseline.ref_counts.clone(),
            ewma_err: baseline.worst_err(),
            ewma_gain: 1.0,
            ewma_residual: 0.0,
            alpha: cfg.ewma_alpha,
            err_margin: cfg.err_margin,
            cm_threshold: cfg.cm_threshold,
            profile_threshold: cfg.profile_threshold,
        }
    }

    /// Absorb one probe report and classify the die's drift state.
    ///
    /// Priority: a common-mode shift is reported first (it is cheap to
    /// fix and can mask profile signals until cancelled); with the
    /// common mode in band, either a profile residual or unexplained
    /// probe-error growth escalates to `Profile`.
    pub fn update(&mut self, rep: &ProbeReport) -> DriftObservation {
        if rep.ref_counts.len() != self.baseline_ref.len() {
            // A shape-broken reference read (failed forward, probe/die
            // mismatch) is not drift telemetry: feeding it into the gain
            // pipeline would read as an enormous common-mode collapse
            // and trigger a maximal — and bogus — T_neu renormalisation
            // on a die that never drifted. Escalate straight to the
            // refit tier instead: the die drains, refits and re-probes,
            // or quarantines if the probe stays broken.
            self.ewma_err = self.alpha * rep.worst_err() + (1.0 - self.alpha) * self.ewma_err;
            return DriftObservation {
                verdict: DriftVerdict::Profile,
                gain: self.ewma_gain,
                residual: self.ewma_residual,
                err: self.ewma_err,
            };
        }
        let gain = common_mode_gain(&self.baseline_ref, &rep.ref_counts);
        let residual = profile_residual(&self.baseline_ref, &rep.ref_counts);
        let a = self.alpha;
        self.ewma_err = a * rep.worst_err() + (1.0 - a) * self.ewma_err;
        self.ewma_gain = a * gain + (1.0 - a) * self.ewma_gain;
        self.ewma_residual = a * residual + (1.0 - a) * self.ewma_residual;
        let verdict = if (self.ewma_gain - 1.0).abs() > self.cm_threshold {
            DriftVerdict::CommonMode
        } else if self.ewma_residual > self.profile_threshold
            || self.ewma_err - self.baseline_err > self.err_margin
        {
            DriftVerdict::Profile
        } else {
            DriftVerdict::Stable
        };
        DriftObservation {
            verdict,
            gain: self.ewma_gain,
            residual: self.ewma_residual,
            err: self.ewma_err,
        }
    }

    /// Probe error the die was enrolled (or last recalibrated) at.
    pub fn baseline_err(&self) -> f64 {
        self.baseline_err
    }

    /// Smoothed probe-error excess over the baseline.
    pub fn err_excess(&self) -> f64 {
        self.ewma_err - self.baseline_err
    }

    /// Called after a renormalisation was applied: the measured gain has
    /// been cancelled in hardware, so the smoothed gain restarts at
    /// unity instead of re-triggering on its own memory.
    pub fn note_renormalized(&mut self) {
        self.ewma_gain = 1.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> FleetConfig {
        FleetConfig {
            ewma_alpha: 0.5,
            err_margin: 0.1,
            cm_threshold: 0.05,
            profile_threshold: 0.08,
            ..Default::default()
        }
    }

    fn baseline() -> ProbeReport {
        ProbeReport {
            err: 0.05,
            ref_counts: vec![100.0, 200.0, 300.0, 400.0],
            t_neu: 56e-6,
            tenant_errs: vec![],
        }
    }

    fn report(err: f64, ref_counts: Vec<f64>) -> ProbeReport {
        ProbeReport { err, ref_counts, t_neu: 56e-6, tenant_errs: vec![] }
    }

    #[test]
    fn stable_on_baseline_repeat() {
        let mut d = DriftDetector::new(&baseline(), &cfg());
        for _ in 0..5 {
            let obs = d.update(&baseline());
            assert_eq!(obs.verdict, DriftVerdict::Stable, "{obs:?}");
        }
    }

    #[test]
    fn common_mode_shift_flags_common_mode() {
        let mut d = DriftDetector::new(&baseline(), &cfg());
        let hot = report(0.05, vec![125.0, 250.0, 375.0, 500.0]); // +25% everywhere
        let mut verdicts = Vec::new();
        for _ in 0..3 {
            verdicts.push(d.update(&hot).verdict);
        }
        assert!(
            verdicts.contains(&DriftVerdict::CommonMode),
            "verdicts {verdicts:?}"
        );
    }

    #[test]
    fn profile_change_flags_profile() {
        let mut d = DriftDetector::new(&baseline(), &cfg());
        // same total (gain 1), columns scrambled
        let scrambled = report(0.05, vec![200.0, 100.0, 400.0, 300.0]);
        let mut last = DriftVerdict::Stable;
        for _ in 0..3 {
            last = d.update(&scrambled).verdict;
        }
        assert_eq!(last, DriftVerdict::Profile);
    }

    #[test]
    fn error_growth_without_reference_shift_flags_profile() {
        let mut d = DriftDetector::new(&baseline(), &cfg());
        let bad = report(0.4, baseline().ref_counts);
        let mut last = DriftVerdict::Stable;
        for _ in 0..4 {
            last = d.update(&bad).verdict;
        }
        assert_eq!(last, DriftVerdict::Profile);
    }

    #[test]
    fn tenant_head_degradation_alone_flags_profile() {
        // the default head probes clean (err at baseline) but a
        // registered tenant's score collapsed: worst_err carries it
        // into the EWMA and the die escalates to the refit tier
        let mut d = DriftDetector::new(&baseline(), &cfg());
        let mut bad = baseline();
        bad.tenant_errs = vec![("easy".into(), 0.04), ("hard".into(), 0.45)];
        let mut last = DriftVerdict::Stable;
        for _ in 0..4 {
            last = d.update(&bad).verdict;
        }
        assert_eq!(last, DriftVerdict::Profile);
    }

    #[test]
    fn ewma_smooths_single_tick_blips() {
        let mut d = DriftDetector::new(&baseline(), &cfg());
        // one noisy probe, then back to baseline: no sticky verdict
        let _ = d.update(&report(0.15, vec![104.0, 208.0, 312.0, 416.0]));
        let mut last = DriftVerdict::Profile;
        for _ in 0..4 {
            last = d.update(&baseline()).verdict;
        }
        assert_eq!(last, DriftVerdict::Stable);
    }

    #[test]
    fn shape_broken_reference_read_escalates_instead_of_renormalizing() {
        // an empty (or wrong-length) reference read means the probe
        // could not run on the die — it must go to the refit tier, not
        // read as a ~0 common-mode gain that renormalisation "fixes"
        let mut d = DriftDetector::new(&baseline(), &cfg());
        let broken = report(0.05, vec![]);
        let obs = d.update(&broken);
        assert_eq!(obs.verdict, DriftVerdict::Profile, "{obs:?}");
        assert!(
            (obs.gain - 1.0).abs() < 1e-12,
            "broken read must not move the gain estimate: {obs:?}"
        );
        let short = report(0.05, vec![100.0, 200.0]);
        assert_eq!(d.update(&short).verdict, DriftVerdict::Profile);
        // a healthy read afterwards still evaluates normally
        assert_eq!(d.update(&baseline()).verdict, DriftVerdict::Stable);
    }

    #[test]
    fn note_renormalized_resets_gain_memory() {
        let mut d = DriftDetector::new(&baseline(), &cfg());
        let hot = report(0.05, vec![150.0, 300.0, 450.0, 600.0]);
        let obs = d.update(&hot);
        assert_eq!(obs.verdict, DriftVerdict::CommonMode);
        d.note_renormalized();
        // hardware now corrected: baseline-level reads stay stable
        let obs2 = d.update(&baseline());
        assert_eq!(obs2.verdict, DriftVerdict::Stable, "{obs2:?}");
    }
}
