//! Source-level concurrency lints for the serving core (`velm lint`).
//!
//! The lock-free hot path is guarded by conventions that the compiler
//! cannot check: every atomic must come from the [`crate::sync`]
//! facade (so the model checker can substitute them), every
//! cross-thread `Relaxed` must carry a written justification, protocol
//! frame tags must stay unique, and metrics booking must stay at one
//! site so the energy-ledger invariant has a single writer sequence to
//! reason about. This module is a small, dependency-free scanner that
//! enforces those conventions over `src/` and backs the `velm lint`
//! CLI subcommand. DESIGN.md §18 documents the rules.
//!
//! The scanner is line-oriented but tracks enough lexical state
//! (strings, char literals, line/block comments, brace depth) to
//! separate code from comments, so doc prose never trips the code
//! rules and justification comments can scope to the block they
//! precede. Raw string literals are the one construct it does not
//! model; none appear on the hot path.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Rule 1: atomics and mutexes must be imported via `crate::sync`.
pub const RULE_FACADE: &str = "facade-imports";
/// Rule 2: every `Ordering::Relaxed` needs a `relaxed-ok:` comment.
pub const RULE_RELAXED: &str = "relaxed-justified";
/// Rule 3: protocol frame tag bytes must be unique.
pub const RULE_TAGS: &str = "frame-tag-unique";
/// Rule 4: metrics booking stays at its one site in the worker.
pub const RULE_BOOKING: &str = "single-booking-site";
/// Rule 5: reactor code never issues a blocking read/write call on a
/// socket it has registered as nonblocking (DESIGN.md §20). The
/// reactor's sockets live in nonblocking mode from the moment the
/// accept thread hands them over, so the blocking `std::io` composites
/// would spin-fail with `WouldBlock` or, worse, silently rely on a
/// timeout that was never set; the poll loop must stick to bare
/// `read`/`write` plus its own buffers.
pub const RULE_REACTOR: &str = "reactor-nonblocking-io";

// Pattern fragments are concatenated at compile time so this file's
// own source never contains the contiguous token it scans for.
const PAT_STD_ATOMIC: &str = concat!("std::sync::", "atomic");
const PAT_STD_MUTEX: &str = concat!("std::sync::", "Mutex");
const PAT_STD_SYNC: &str = concat!("std::", "sync::");
const PAT_RELAXED: &str = concat!("Ordering::", "Relaxed");
const MARKER: &str = concat!("relaxed-", "ok:");
const TEST_REGION: &str = concat!("#[cfg(", "test)]");

/// Files allowed to name `std::sync` primitives directly: the facade
/// itself and the modeled implementation it swaps in.
const FACADE_ALLOWLIST: &[&str] = &["sync.rs", "testing/model.rs"];

/// Path (relative to `src/`) holding the protocol frame tags.
const FRAME_FILE: &str = "protocol/frame.rs";
/// Frame tag constants expected at minimum; a refactor that silently
/// drops the tag table should fail the lint, not pass it vacuously.
/// 28 through PR 9; PR 10 adds the correlation envelope, handshake,
/// tenant-update and streaming-reply tags (DESIGN.md §20).
const MIN_FRAME_TAGS: usize = 37;

/// Path (relative to `src/`) holding the connection reactor.
const REACTOR_FILE: &str = "coordinator/reactor.rs";
/// Blocking I/O composites banned from the reactor's non-test code:
/// each loops internally until satisfied, which deadlocks or busy-fails
/// on a nonblocking socket. Fragments are concatenated so this file's
/// own source never contains the scanned token.
const REACTOR_BANNED_CALLS: &[&str] = &[
    concat!(".read_", "exact("),
    concat!(".read_", "to_end("),
    concat!(".read_", "to_string("),
    concat!(".write_", "all("),
    concat!(".set_read_", "timeout("),
];

/// Path (relative to `src/`) that owns metrics booking.
const BOOKING_FILE: &str = "coordinator/worker.rs";
/// Metrics files whose own (non-test) code may mention booking calls.
const BOOKING_ALLOWLIST: &[&str] = &["coordinator/worker.rs", "coordinator/metrics.rs"];
/// Booking calls and how many non-test call sites the worker owns.
/// `record_energy` books twice: once on fleet metrics, once on the
/// requesting tenant's gauge.
const BOOKING_CALLS: &[(&str, usize)] = &[
    (".record_batch(", 1),
    (".record_conversions(", 1),
    (".record_energy(", 2),
    (".record_gov_fj_saved(", 1),
];

/// One lint violation, pointing at a file and line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Path relative to `src/`, forward slashes.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Which rule fired (one of the `RULE_*` constants).
    pub rule: &'static str,
    /// Human-readable explanation.
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "src/{}:{}: [{}] {}",
            self.file, self.line, self.rule, self.message
        )
    }
}

/// Aggregate result of a lint run.
#[derive(Debug, Default)]
pub struct LintReport {
    /// All violations, in file order.
    pub findings: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
    /// Non-test `Ordering::Relaxed` sites seen.
    pub relaxed_sites: usize,
    /// How many of those carried a justification.
    pub justified_sites: usize,
}

impl LintReport {
    /// True when no rule fired.
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }
}

/// Lint every `.rs` file under `<manifest_root>/src`.
pub fn lint_tree(manifest_root: &Path) -> Result<LintReport> {
    let src = manifest_root.join("src");
    let mut files = Vec::new();
    collect_rs_files(&src, &mut files)
        .with_context(|| format!("walking {}", src.display()))?;
    files.sort();

    let mut report = LintReport::default();
    for path in &files {
        let text = fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let rel = path
            .strip_prefix(&src)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        lint_source(&rel, &text, &mut report);
        report.files_scanned += 1;
    }
    Ok(report)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// One scanned line: code with strings/comments stripped, the comment
/// text, and the brace depth at the start of the line.
struct ScanLine {
    code: String,
    comment: String,
    depth_before: usize,
}

/// Lint a single source file (exposed so tests can seed violations
/// from in-memory strings). `rel` is the path relative to `src/`.
pub fn lint_source(rel: &str, text: &str, report: &mut LintReport) {
    let lines = scan_lines(text);
    // Everything from the first test-region attribute to EOF is test
    // code: exempt from the import and justification rules (tests may
    // poke internals), and not a booking site.
    let test_start = text
        .lines()
        .position(|l| l.trim_start().starts_with(TEST_REGION))
        .unwrap_or(usize::MAX);

    if !FACADE_ALLOWLIST.contains(&rel) {
        check_facade(rel, &lines, test_start, report);
        check_relaxed(rel, &lines, test_start, report);
    }
    if rel == FRAME_FILE {
        check_frame_tags(rel, &lines, test_start, report);
    }
    if rel == REACTOR_FILE {
        check_reactor_io(rel, &lines, test_start, report);
    }
    check_booking(rel, &lines, test_start, report);
}

/// Rule 5: the reactor's non-test code must not call the blocking
/// `std::io` composites on its (nonblocking) sockets.
fn check_reactor_io(rel: &str, lines: &[ScanLine], test_start: usize, report: &mut LintReport) {
    for (i, line) in lines.iter().enumerate() {
        if i >= test_start {
            break;
        }
        for call in REACTOR_BANNED_CALLS {
            if line.code.contains(call) {
                report.findings.push(Finding {
                    file: rel.to_string(),
                    line: i + 1,
                    rule: RULE_REACTOR,
                    message: format!(
                        "`{call}` in reactor code; the reactor's sockets are \
                         nonblocking, so blocking composites would spin on \
                         WouldBlock — use bare read/write with the \
                         connection's buffers instead"
                    ),
                });
            }
        }
    }
}

/// Rule 1: no direct `std::sync::atomic` / `std::sync::Mutex` use.
fn check_facade(rel: &str, lines: &[ScanLine], test_start: usize, report: &mut LintReport) {
    // Multi-line `use std::sync::{...};` capture: accumulate from the
    // opening line until the terminating semicolon.
    let mut use_capture: Option<(usize, String)> = None;
    for (i, line) in lines.iter().enumerate() {
        if i >= test_start {
            break;
        }
        let code = &line.code;
        if code.contains(PAT_STD_ATOMIC) || code.contains(PAT_STD_MUTEX) {
            report.findings.push(Finding {
                file: rel.to_string(),
                line: i + 1,
                rule: RULE_FACADE,
                message: "direct std::sync atomic/Mutex use; import from \
                          crate::sync so the model checker can substitute it"
                    .to_string(),
            });
            continue;
        }
        if let Some((start, captured)) = &mut use_capture {
            captured.push_str(code);
            if code.contains(';') {
                flag_use_capture(rel, *start, captured, report);
                use_capture = None;
            }
            continue;
        }
        let trimmed = code.trim_start();
        if trimmed.starts_with("use ") && trimmed.contains(PAT_STD_SYNC) {
            if code.contains(';') {
                flag_use_capture(rel, i + 1, code, report);
            } else {
                use_capture = Some((i + 1, code.clone()));
            }
        }
    }
}

fn flag_use_capture(rel: &str, line: usize, captured: &str, report: &mut LintReport) {
    if captured.contains("Mutex") || captured.contains("atomic") {
        report.findings.push(Finding {
            file: rel.to_string(),
            line,
            rule: RULE_FACADE,
            message: "std::sync import brings in Mutex/atomic items; \
                      route them through crate::sync instead"
                .to_string(),
        });
    }
}

/// Rule 2: every non-test `Ordering::Relaxed` must be covered by a
/// `relaxed-ok:` justification — on the same line, or in a comment
/// earlier in the same (or an enclosing) block. Block scoping means an
/// impl-level comment can justify a family of related counter sites
/// without repeating itself per line, while a file-level (depth 0)
/// comment is deliberately NOT accepted: a justification must sit
/// inside the item it justifies.
fn check_relaxed(rel: &str, lines: &[ScanLine], test_start: usize, report: &mut LintReport) {
    let mut active: Vec<usize> = Vec::new(); // depths of live justifications
    for (i, line) in lines.iter().enumerate() {
        if i >= test_start {
            break;
        }
        let depth = line.depth_before;
        active.retain(|&d| depth >= d);
        let has_marker = line.comment.contains(MARKER);
        if has_marker && depth >= 1 {
            active.push(depth);
        }
        let sites = line.code.matches(PAT_RELAXED).count();
        if sites == 0 {
            continue;
        }
        report.relaxed_sites += sites;
        if has_marker || !active.is_empty() {
            report.justified_sites += sites;
        } else {
            report.findings.push(Finding {
                file: rel.to_string(),
                line: i + 1,
                rule: RULE_RELAXED,
                message: format!(
                    "{PAT_RELAXED} without a `{MARKER}` justification in \
                     scope; state why relaxed ordering is sound here"
                ),
            });
        }
    }
}

/// Rule 3: frame tag constants (`T_*`/`R_*: u8`) must be unique, and
/// the tag table must not silently shrink below [`MIN_FRAME_TAGS`].
fn check_frame_tags(rel: &str, lines: &[ScanLine], test_start: usize, report: &mut LintReport) {
    let mut seen: Vec<(String, u8, usize)> = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        if i >= test_start {
            break;
        }
        if let Some((name, value)) = parse_tag_const(&line.code) {
            if let Some((other, _, first_line)) =
                seen.iter().find(|(_, v, _)| *v == value)
            {
                report.findings.push(Finding {
                    file: rel.to_string(),
                    line: i + 1,
                    rule: RULE_TAGS,
                    message: format!(
                        "duplicate frame tag 0x{value:02X}: {name} collides \
                         with {other} (line {first_line})"
                    ),
                });
            }
            seen.push((name, value, i + 1));
        }
    }
    if seen.len() < MIN_FRAME_TAGS {
        report.findings.push(Finding {
            file: rel.to_string(),
            line: 1,
            rule: RULE_TAGS,
            message: format!(
                "only {} frame tag constants found (expected at least \
                 {MIN_FRAME_TAGS}); did a refactor move or rename the tag table?",
                seen.len()
            ),
        });
    }
}

/// Parse `const T_FOO: u8 = 0xNN;` (optionally `pub`).
fn parse_tag_const(code: &str) -> Option<(String, u8)> {
    let t = code.trim();
    let rest = t
        .strip_prefix("pub const ")
        .or_else(|| t.strip_prefix("const "))?;
    let (name, rest) = rest.split_once(':')?;
    let name = name.trim();
    if !(name.starts_with("T_") || name.starts_with("R_")) {
        return None;
    }
    let (ty, rest) = rest.split_once('=')?;
    if ty.trim() != "u8" {
        return None;
    }
    let value = rest.trim().trim_end_matches(';').trim();
    let value = value.strip_prefix("0x")?;
    u8::from_str_radix(value, 16).ok().map(|v| (name.to_string(), v))
}

/// Rule 4: the worker owns metrics booking. Its non-test code must
/// contain exactly the expected call sites, and no other file's
/// non-test code may book at all (the metrics module itself excepted —
/// it defines the methods and exercises them in doc examples).
fn check_booking(rel: &str, lines: &[ScanLine], test_start: usize, report: &mut LintReport) {
    let is_owner = rel == BOOKING_FILE;
    if !is_owner && BOOKING_ALLOWLIST.contains(&rel) {
        return;
    }
    for &(call, expected) in BOOKING_CALLS {
        let mut hits: Vec<usize> = Vec::new();
        for (i, line) in lines.iter().enumerate() {
            if i >= test_start {
                break;
            }
            for _ in line.code.matches(call) {
                hits.push(i + 1);
            }
        }
        if is_owner {
            if hits.len() != expected {
                report.findings.push(Finding {
                    file: rel.to_string(),
                    line: hits.first().copied().unwrap_or(1),
                    rule: RULE_BOOKING,
                    message: format!(
                        "expected exactly {expected} `{call}` site(s) in the \
                         worker, found {} (lines {hits:?}); booking must stay \
                         at one place so the ledger invariant has a single \
                         writer sequence",
                        hits.len()
                    ),
                });
            }
        } else if let Some(&first) = hits.first() {
            report.findings.push(Finding {
                file: rel.to_string(),
                line: first,
                rule: RULE_BOOKING,
                message: format!(
                    "`{call}` outside {BOOKING_FILE}; metrics booking is \
                     owned by the worker loop"
                ),
            });
        }
    }
}

/// Split a source file into per-line code/comment text with brace
/// depth, tracking strings, char literals, lifetimes, and line/block
/// comments across lines.
fn scan_lines(text: &str) -> Vec<ScanLine> {
    let mut out = Vec::new();
    let mut in_block_comment = false;
    let mut depth: usize = 0;
    for raw in text.lines() {
        let depth_before = depth;
        let mut code = String::new();
        let mut comment = String::new();
        let chars: Vec<char> = raw.chars().collect();
        let mut i = 0;
        while i < chars.len() {
            if in_block_comment {
                if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                    in_block_comment = false;
                    i += 2;
                } else {
                    comment.push(chars[i]);
                    i += 1;
                }
                continue;
            }
            let c = chars[i];
            match c {
                '/' if chars.get(i + 1) == Some(&'/') => {
                    comment.extend(&chars[i + 2..]);
                    i = chars.len();
                }
                '/' if chars.get(i + 1) == Some(&'*') => {
                    in_block_comment = true;
                    i += 2;
                }
                '"' => {
                    // Skip the string body; leave a placeholder so
                    // token adjacency is not created by the removal.
                    code.push_str("\"\"");
                    i += 1;
                    while i < chars.len() {
                        match chars[i] {
                            '\\' => i += 2,
                            '"' => {
                                i += 1;
                                break;
                            }
                            _ => i += 1,
                        }
                    }
                }
                '\'' => {
                    // Char literal vs lifetime: a char literal closes
                    // with a quote within a few chars; a lifetime is
                    // an identifier with no closing quote.
                    if chars.get(i + 1) == Some(&'\\') {
                        // Escaped char literal: '\n', '\'', '\\', '\u{..}'.
                        // Consume the quote, backslash, and escape head
                        // unconditionally (the head may itself be a quote),
                        // then scan to the closing quote.
                        code.push_str("' '");
                        i += 3;
                        while i < chars.len() && chars[i] != '\'' {
                            i += 1;
                        }
                        i += 1;
                    } else if chars.get(i + 2) == Some(&'\'') {
                        code.push_str("' '");
                        i += 3;
                    } else {
                        // Lifetime: keep it as code text.
                        code.push(c);
                        i += 1;
                    }
                }
                '{' => {
                    depth += 1;
                    code.push(c);
                    i += 1;
                }
                '}' => {
                    depth = depth.saturating_sub(1);
                    code.push(c);
                    i += 1;
                }
                _ => {
                    code.push(c);
                    i += 1;
                }
            }
        }
        out.push(ScanLine {
            code,
            comment,
            depth_before,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_str(rel: &str, text: &str) -> LintReport {
        let mut report = LintReport::default();
        lint_source(rel, text, &mut report);
        report
    }

    // Seeded sources build the banned tokens by concatenation so this
    // test module does not itself trip the facade rule's source scan.
    fn std_atomic_use() -> String {
        format!("use {PAT_STD_ATOMIC}::AtomicU64;\n")
    }

    #[test]
    fn facade_rule_flags_direct_atomic_import() {
        let src = std_atomic_use();
        let r = lint_str("coordinator/fake.rs", &src);
        assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
        assert_eq!(r.findings[0].rule, RULE_FACADE);
        assert_eq!(r.findings[0].line, 1);
    }

    #[test]
    fn facade_rule_flags_multiline_std_sync_use() {
        let src = format!(
            "use {PAT_STD_SYNC}{{\n    mpsc,\n    Mutex,\n}};\n"
        );
        let r = lint_str("coordinator/fake.rs", &src);
        assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
        assert_eq!(r.findings[0].rule, RULE_FACADE);
    }

    #[test]
    fn facade_rule_allows_arc_and_mpsc_and_allowlisted_files() {
        let benign = format!("use {PAT_STD_SYNC}{{mpsc, Arc}};\n");
        assert!(lint_str("coordinator/fake.rs", &benign).is_clean());
        let banned = std_atomic_use();
        assert!(lint_str("sync.rs", &banned).is_clean());
        assert!(lint_str("testing/model.rs", &banned).is_clean());
    }

    #[test]
    fn facade_rule_ignores_comments_and_test_code() {
        let src = format!(
            "// mentions {PAT_STD_MUTEX} in prose only\nfn f() {{}}\n\
             {TEST_REGION}\nmod tests {{\n    use {PAT_STD_ATOMIC}::AtomicU64;\n}}\n"
        );
        assert!(lint_str("coordinator/fake.rs", &src).is_clean());
    }

    #[test]
    fn relaxed_rule_flags_unjustified_sites() {
        let src = format!(
            "fn f(x: &AtomicU64) -> u64 {{\n    x.load({PAT_RELAXED})\n}}\n"
        );
        let r = lint_str("coordinator/fake.rs", &src);
        assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
        assert_eq!(r.findings[0].rule, RULE_RELAXED);
        assert_eq!(r.findings[0].line, 2);
        assert_eq!(r.relaxed_sites, 1);
        assert_eq!(r.justified_sites, 0);
    }

    #[test]
    fn relaxed_rule_accepts_same_line_and_scoped_justifications() {
        let src = format!(
            "fn f(x: &AtomicU64) -> u64 {{\n    \
             x.load({PAT_RELAXED}) // {MARKER} monotone counter\n}}\n\
             impl Foo {{\n    // {MARKER} independent gauges\n    \
             fn g(&self) -> u64 {{\n        self.a.load({PAT_RELAXED})\n    }}\n}}\n"
        );
        let r = lint_str("coordinator/fake.rs", &src);
        assert!(r.is_clean(), "{:?}", r.findings);
        assert_eq!(r.relaxed_sites, 2);
        assert_eq!(r.justified_sites, 2);
    }

    #[test]
    fn relaxed_rule_expires_justification_when_scope_closes() {
        let src = format!(
            "fn f(x: &AtomicU64) {{\n    // {MARKER} only inside f\n    \
             x.store(1, {PAT_RELAXED});\n}}\n\
             fn g(x: &AtomicU64) -> u64 {{\n    x.load({PAT_RELAXED})\n}}\n"
        );
        let r = lint_str("coordinator/fake.rs", &src);
        assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
        assert_eq!(r.findings[0].line, 6);
    }

    #[test]
    fn relaxed_rule_rejects_file_level_justification() {
        let src = format!(
            "// {MARKER} too broad, whole file\n\
             fn f(x: &AtomicU64) -> u64 {{\n    x.load({PAT_RELAXED})\n}}\n"
        );
        let r = lint_str("coordinator/fake.rs", &src);
        assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
        assert_eq!(r.findings[0].rule, RULE_RELAXED);
    }

    #[test]
    fn frame_tags_must_be_unique() {
        let src = "pub const FRAME_MAGIC: u8 = 0xF1;\n\
                   const T_PING: u8 = 0x01;\n\
                   const T_INFER: u8 = 0x02;\n\
                   const R_PONG: u8 = 0x81;\n\
                   const R_CLASH: u8 = 0x02;\n";
        let r = lint_str("protocol/frame.rs", src);
        let dup = r
            .findings
            .iter()
            .find(|f| f.rule == RULE_TAGS && f.message.contains("duplicate"))
            .expect("duplicate tag finding");
        assert!(dup.message.contains("0x02"), "{}", dup.message);
        assert_eq!(dup.line, 5);
        // The small seeded table also trips the minimum-count guard.
        assert!(r
            .findings
            .iter()
            .any(|f| f.rule == RULE_TAGS && f.message.contains("at least")));
    }

    #[test]
    fn frame_tag_rule_only_applies_to_frame_file() {
        let src = "const T_A: u8 = 0x01;\nconst T_B: u8 = 0x01;\n";
        assert!(lint_str("protocol/stats.rs", src).is_clean());
    }

    #[test]
    fn booking_outside_worker_is_flagged() {
        let src = "fn sneak(m: &Metrics) {\n    m.record_conversions(1);\n}\n";
        let r = lint_str("coordinator/router.rs", src);
        assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
        assert_eq!(r.findings[0].rule, RULE_BOOKING);
        assert_eq!(r.findings[0].line, 2);
    }

    #[test]
    fn booking_site_count_in_worker_is_enforced() {
        let src = "fn run(s: &S) {\n\
                   \x20   s.metrics.record_batch(1, true);\n\
                   \x20   s.metrics.record_conversions(1);\n\
                   \x20   s.metrics.record_energy(1, 1);\n\
                   \x20   t.metrics.record_energy(1);\n\
                   \x20   s.metrics.record_gov_fj_saved(1);\n\
                   \x20   s.metrics.record_conversions(1);\n}\n";
        let r = lint_str("coordinator/worker.rs", src);
        assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
        assert_eq!(r.findings[0].rule, RULE_BOOKING);
        assert!(r.findings[0].message.contains("record_conversions"));
    }

    #[test]
    fn booking_in_tests_is_exempt() {
        let src = format!(
            "fn run(s: &S) {{\n\
             \x20   s.metrics.record_batch(1, true);\n\
             \x20   s.metrics.record_conversions(1);\n\
             \x20   s.metrics.record_energy(1, 1);\n\
             \x20   t.metrics.record_energy(1);\n\
             \x20   s.metrics.record_gov_fj_saved(1);\n}}\n\
             {TEST_REGION}\nmod tests {{\n    \
             fn extra(m: &M) {{ m.record_conversions(5); }}\n}}\n"
        );
        assert!(lint_str("coordinator/worker.rs", &src).is_clean());
    }

    #[test]
    fn reactor_io_rule_flags_blocking_calls() {
        // Seed every banned composite once; each must fire exactly once.
        for call in REACTOR_BANNED_CALLS {
            let src = format!(
                "fn f(s: &mut TcpStream, buf: &mut Vec<u8>) {{\n    s{call}buf).unwrap();\n}}\n"
            );
            let r = lint_str("coordinator/reactor.rs", &src);
            assert_eq!(r.findings.len(), 1, "{call}: {:?}", r.findings);
            assert_eq!(r.findings[0].rule, RULE_REACTOR);
            assert_eq!(r.findings[0].line, 2);
        }
    }

    #[test]
    fn reactor_io_rule_scopes_to_the_reactor_and_its_code_region() {
        let call = REACTOR_BANNED_CALLS[0];
        // Other files may use blocking composites (the legacy v0 path
        // in server.rs does, on sockets it keeps in blocking mode).
        let src = format!("fn f(s: &mut TcpStream, b: &mut [u8]) {{\n    s{call}b).unwrap();\n}}\n");
        assert!(lint_str("coordinator/server.rs", &src).is_clean());
        // Comments and test code in the reactor itself are exempt.
        let src = format!(
            "// prose mentioning s{call}b) only\nfn f() {{}}\n\
             {TEST_REGION}\nmod tests {{\n    fn g(s: &mut T, b: &mut [u8]) {{ s{call}b).unwrap(); }}\n}}\n"
        );
        assert!(lint_str("coordinator/reactor.rs", &src).is_clean());
    }

    #[test]
    fn scanner_separates_strings_comments_and_depth() {
        let lines = scan_lines(
            "fn f() {\n    let s = \"{ not a brace }\"; // trailing { comment\n    /* block {\n       still block */ let c = '{';\n}\n",
        );
        assert_eq!(lines[0].depth_before, 0);
        assert_eq!(lines[1].depth_before, 1);
        assert!(!lines[1].code.contains("not a brace"));
        assert!(lines[1].comment.contains("trailing"));
        assert_eq!(lines[2].depth_before, 1);
        assert_eq!(lines[4].depth_before, 1);
        assert_eq!(lines.last().unwrap().code.trim(), "}");
    }

    /// The tree itself must be clean: this is the in-repo guarantee
    /// that `velm lint` passes on every commit, and it doubles as the
    /// regression test for the sweep that moved all atomics onto the
    /// facade.
    #[test]
    fn lint_tree_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let report = lint_tree(root).expect("lint walk");
        assert!(
            report.is_clean(),
            "lint findings:\n{}",
            report
                .findings
                .iter()
                .map(|f| f.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
        assert!(report.files_scanned > 20, "scanned {}", report.files_scanned);
        assert!(report.relaxed_sites > 10, "sites {}", report.relaxed_sites);
        assert_eq!(report.relaxed_sites, report.justified_sites);
    }
}
