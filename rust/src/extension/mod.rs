//! Section V: input-dimension and hidden-layer extension by weight reuse.
//!
//! A physical k x N mirror array serves a d x L virtual projection
//! (d, L <= k*N) by circularly rotating the input registers (hidden
//! extension, Fig. 12) and the output register bank (input extension,
//! Fig. 13), accumulating counter outputs across ceil(d/k) chunks:
//!
//!   * hidden block m (of ceil(L/N)): input registers rotated left m
//!     times, so neuron j sees weight row (i - m) mod k — the paper's
//!     `W_{m,0}` row rotation.
//!   * input chunk c (of ceil(d/k)): counter outputs rotated left c
//!     times before accumulation, undoing the `W_{0,c}` column rotation.
//!
//! Faithful caveat (as in the paper): the accumulated activation is
//! `sum_c g(W_c x_c)`, not `g(sum_c W_c x_c)` — exact in the linear
//! region of the neuron, approximate once chunks saturate individually.

use crate::chip::{dac, ChipModel};
use crate::chip::mismatch::MismatchMatrix;
use crate::elm::train::HiddenLayer;

/// Tiling schedule for a virtual d x L projection on a k x N die.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RotationPlan {
    /// Physical input channels.
    pub k: usize,
    /// Physical hidden neurons.
    pub n: usize,
    /// Virtual input dimension.
    pub d: usize,
    /// Virtual hidden width.
    pub l: usize,
}

impl RotationPlan {
    pub fn new(k: usize, n: usize, d: usize, l: usize) -> Result<Self, String> {
        if d == 0 || l == 0 {
            return Err("virtual dimensions must be positive".into());
        }
        if d > k * n {
            return Err(format!("d={d} exceeds reusable weights k*N={}", k * n));
        }
        if l > k * n {
            return Err(format!("L={l} exceeds reusable weights k*N={}", k * n));
        }
        Ok(RotationPlan { k, n, d, l })
    }

    /// ceil(L/N) hidden blocks.
    pub fn hidden_blocks(&self) -> usize {
        self.l.div_ceil(self.n)
    }

    /// ceil(d/k) input chunks.
    pub fn input_chunks(&self) -> usize {
        self.d.div_ceil(self.k)
    }

    /// Chip conversions per virtual forward.
    pub fn passes(&self) -> usize {
        self.hidden_blocks() * self.input_chunks()
    }

    /// The virtual weight this schedule realises at global (i, j):
    /// `W_virt[i][j] = W[(i_loc - m) mod k][(j_loc + c) mod N]` with
    /// m = j / N, c = i / k. Single source of truth for tests.
    pub fn virtual_weight(&self, mm: &MismatchMatrix, i: usize, j: usize, t_k: f64) -> f64 {
        debug_assert!(i < self.d && j < self.l);
        let (c, i_loc) = (i / self.k, i % self.k);
        let (m, j_loc) = (j / self.n, j % self.n);
        let row = (i_loc + self.k - m % self.k) % self.k;
        let col = (j_loc + c) % self.n;
        mm.weight(row, col, t_k)
    }
}

/// A die wrapped with the rotation schedule: presents a d x L hidden
/// layer built from k x N physical weights.
pub struct VirtualChip {
    pub chip: ChipModel,
    pub plan: RotationPlan,
}

impl VirtualChip {
    pub fn new(chip: ChipModel, d: usize, l: usize) -> Result<Self, String> {
        let plan = RotationPlan::new(chip.cfg.d, chip.cfg.l, d, l)?;
        Ok(VirtualChip { chip, plan })
    }

    /// Virtual forward: d codes in, L accumulated counts out, running
    /// `passes()` physical conversions through the SPI rotation circuits.
    ///
    /// A dimension mismatch is an `Err`, not a panic: the caller may be
    /// a worker thread that owns a die, and a malformed request must
    /// not take the die down with it.
    pub fn forward(&mut self, codes: &[u16]) -> Result<Vec<u32>, String> {
        let p = self.plan;
        if codes.len() != p.d {
            return Err(format!(
                "virtual forward expected {} codes, got {}",
                p.d,
                codes.len()
            ));
        }
        let mut out = vec![0u32; p.l];
        for m in 0..p.hidden_blocks() {
            // accumulator bank gathers over input chunks for this block
            let mut bank = crate::chip::spi::OutputBank::new(p.n);
            for c in 0..p.input_chunks() {
                // chunk c of the virtual input, padded with code 0
                // (S2 shuts padded rows off — exact)
                let mut chunk = vec![0u16; p.k];
                for i_loc in 0..p.k {
                    let i = c * p.k + i_loc;
                    if i < p.d {
                        chunk[i_loc] = codes[i];
                    }
                }
                // Fig. 12: load then pulse Rotation_Control m times
                self.chip.load_input(&chunk);
                for _ in 0..m % p.k {
                    self.chip.input_regs.rotate();
                }
                let counts = self.chip.convert();
                // Fig. 13: latch, rotate c times, accumulate
                bank.latch(&counts);
                for _ in 0..c % p.n {
                    bank.clk_r();
                }
                bank.clk_a();
            }
            let acc = bank.read_and_clear();
            for j_loc in 0..p.n {
                let j = m * p.n + j_loc;
                if j < p.l {
                    out[j] = acc[j_loc];
                }
            }
        }
        Ok(out)
    }

    /// Features in [-1,1]^d -> virtual hidden counts.
    pub fn forward_features(&mut self, xs: &[f64]) -> Result<Vec<u32>, String> {
        if xs.len() != self.plan.d {
            return Err(format!(
                "virtual forward expected {} features, got {}",
                self.plan.d,
                xs.len()
            ));
        }
        let codes: Vec<u16> = xs
            .iter()
            .map(|&x| dac::feature_to_code(x, &self.chip.cfg))
            .collect();
        self.forward(&codes)
    }
}

impl HiddenLayer for VirtualChip {
    fn input_dim(&self) -> usize {
        self.plan.d
    }

    fn hidden_dim(&self) -> usize {
        self.plan.l
    }

    fn transform(&mut self, x: &[f64]) -> Vec<f64> {
        // same O(1) activation scaling as ChipHidden (lambda parity).
        // Training assembles H from its own feature matrix, so a
        // dimension mismatch here is a caller bug, not request input.
        let scale = 1.0 / self.chip.cfg.cap() as f64;
        self.forward_features(x)
            .expect("training features match the rotation plan")
            .iter()
            .map(|&v| v as f64 * scale)
            .collect()
    }
}

/// A die as the serving fleet holds it: the bare physical chip when the
/// requested dims fit exactly (fast path, no rotation peripherals in
/// the loop), or a [`VirtualChip`] when the Section V rotation serves a
/// larger projection. Probing, recalibration and serving all flow
/// through [`ServeChip::forward`], so fleet health keeps working on
/// virtual dies (DESIGN.md §13).
pub enum ServeChip {
    Physical(ChipModel),
    Virtual(VirtualChip),
}

impl ServeChip {
    /// Wrap `chip` so it serves a d x l projection; picks the physical
    /// fast path when the dims match the die exactly.
    pub fn new(chip: ChipModel, d: usize, l: usize) -> Result<Self, String> {
        if d == chip.cfg.d && l == chip.cfg.l {
            Ok(ServeChip::Physical(chip))
        } else {
            Ok(ServeChip::Virtual(VirtualChip::new(chip, d, l)?))
        }
    }

    /// A physical die served at its fabricated dimensions.
    pub fn physical(chip: ChipModel) -> Self {
        ServeChip::Physical(chip)
    }

    /// Whether requests run a single physical conversion (no rotation).
    /// Only physical dies may use the fixed-shape AOT artifact.
    pub fn is_physical(&self) -> bool {
        matches!(self, ServeChip::Physical(_))
    }

    pub fn chip(&self) -> &ChipModel {
        match self {
            ServeChip::Physical(c) => c,
            ServeChip::Virtual(v) => &v.chip,
        }
    }

    pub fn chip_mut(&mut self) -> &mut ChipModel {
        match self {
            ServeChip::Physical(c) => c,
            ServeChip::Virtual(v) => &mut v.chip,
        }
    }

    /// The rotation schedule, if this die serves virtually.
    pub fn plan(&self) -> Option<RotationPlan> {
        match self {
            ServeChip::Physical(_) => None,
            ServeChip::Virtual(v) => Some(v.plan),
        }
    }

    /// Physical conversions per served request.
    pub fn passes(&self) -> usize {
        self.plan().map_or(1, |p| p.passes())
    }

    /// Input dimension requests must carry.
    pub fn input_dim(&self) -> usize {
        match self {
            ServeChip::Physical(c) => c.cfg.d,
            ServeChip::Virtual(v) => v.plan.d,
        }
    }

    /// Hidden width responses are scored over.
    pub fn hidden_dim(&self) -> usize {
        match self {
            ServeChip::Physical(c) => c.cfg.l,
            ServeChip::Virtual(v) => v.plan.l,
        }
    }

    /// One served conversion: d codes -> hidden counts, through the
    /// rotation schedule when the die is virtual. Dimension mismatches
    /// are `Err` on both arms so a malformed request cannot panic the
    /// worker thread that owns the die.
    pub fn forward(&mut self, codes: &[u16]) -> Result<Vec<u32>, String> {
        match self {
            ServeChip::Physical(c) => {
                if codes.len() != c.cfg.d {
                    return Err(format!(
                        "forward expected {} codes, got {}",
                        c.cfg.d,
                        codes.len()
                    ));
                }
                Ok(c.forward(codes))
            }
            ServeChip::Virtual(v) => v.forward(codes),
        }
    }

    /// Features in [-1,1]^d -> hidden counts (probe/refit path).
    pub fn forward_features(&mut self, xs: &[f64]) -> Result<Vec<u32>, String> {
        match self {
            ServeChip::Virtual(v) => v.forward_features(xs),
            ServeChip::Physical(c) => {
                if xs.len() != c.cfg.d {
                    return Err(format!(
                        "forward expected {} features, got {}",
                        c.cfg.d,
                        xs.len()
                    ));
                }
                Ok(c.forward_features(xs))
            }
        }
    }

    /// One training/refit row of H: features -> hidden counts ->
    /// counter-cap scaling with optional eq. 26 normalisation. The
    /// single assembly path shared by [`ServeHidden`] (fleet training)
    /// and `fleet::calibrate::refit_head`, so the two can never diverge
    /// bit-wise.
    pub fn assemble_row(&mut self, x: &[f64], normalize: bool) -> Result<Vec<f64>, String> {
        let codes: Vec<u16> = x
            .iter()
            .map(|&v| dac::feature_to_code(v, &self.chip().cfg))
            .collect();
        let h = self.forward(&codes)?;
        let scale = 1.0 / self.chip().cfg.cap() as f64;
        Ok(if normalize {
            crate::elm::secondstage::normalize_h(
                &h,
                crate::elm::secondstage::codes_sum(&codes),
            )
            .into_iter()
            .map(|v| v * scale)
            .collect()
        } else {
            h.iter().map(|&v| v as f64 * scale).collect()
        })
    }
}

/// Training-side view of a [`ServeChip`]: the `HiddenLayer` the
/// coordinator trains each die through, with the same counter-cap
/// activation scaling and optional eq. 26 normalisation as
/// `elm::ChipHidden` — so physical and virtual dies train identically.
pub struct ServeHidden {
    pub die: ServeChip,
    pub normalize: bool,
}

impl HiddenLayer for ServeHidden {
    fn input_dim(&self) -> usize {
        self.die.input_dim()
    }

    fn hidden_dim(&self) -> usize {
        self.die.hidden_dim()
    }

    fn transform(&mut self, x: &[f64]) -> Vec<f64> {
        self.die
            .assemble_row(x, self.normalize)
            .expect("training features match the serving plan")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::{counter, neuron};
    use crate::config::{ChipConfig, Transfer};

    fn die(k: usize, n: usize, seed: u64) -> ChipModel {
        let cfg = ChipConfig::default()
            .with_dims(k, n)
            .with_b(12)
            .with_mode(Transfer::Quadratic);
        ChipModel::fabricate(cfg, seed)
    }

    /// Software reference: apply the per-chunk quantised transfer with
    /// the plan's virtual weights and accumulate — independent of the
    /// SPI rotation circuits under test.
    fn reference_forward(chip: &ChipModel, plan: &RotationPlan, codes: &[u16]) -> Vec<u32> {
        let cfg = &chip.cfg;
        let t = cfg.temp_k;
        let mut out = vec![0u32; plan.l];
        for m in 0..plan.hidden_blocks() {
            for j_loc in 0..plan.n {
                let j = m * plan.n + j_loc;
                if j >= plan.l {
                    continue;
                }
                for c in 0..plan.input_chunks() {
                    let mut z = 0.0;
                    for i_loc in 0..plan.k {
                        let i = c * plan.k + i_loc;
                        if i >= plan.d {
                            continue;
                        }
                        let w = plan.virtual_weight(&chip.mismatch, i, j, t);
                        z += dac::dac_current(codes[i], cfg) * w;
                    }
                    let f = neuron::with_neuron_mismatch(
                        neuron::f_sp(z, cfg),
                        chip.mismatch.kneu_gain(j_loc),
                    );
                    out[j] += counter::count_window(f, cfg.t_neu(), cfg.cap());
                }
            }
        }
        out
    }

    fn codes_pattern(d: usize, seed: u64) -> Vec<u16> {
        let mut rng = crate::util::prng::Prng::new(seed);
        (0..d).map(|_| rng.usize(1024) as u16).collect()
    }

    #[test]
    fn plan_validates_bounds() {
        assert!(RotationPlan::new(4, 4, 16, 16).is_ok());
        assert!(RotationPlan::new(4, 4, 17, 4).is_err());
        assert!(RotationPlan::new(4, 4, 4, 17).is_err());
        assert!(RotationPlan::new(4, 4, 0, 4).is_err());
    }

    #[test]
    fn plan_pass_counts() {
        let p = RotationPlan::new(8, 8, 20, 17).unwrap();
        assert_eq!(p.input_chunks(), 3);
        assert_eq!(p.hidden_blocks(), 3);
        assert_eq!(p.passes(), 9);
    }

    #[test]
    fn identity_when_dims_fit() {
        // d <= k, L <= N: the virtual chip is exactly the physical chip.
        let mut chip = die(8, 8, 1);
        let codes = codes_pattern(8, 2);
        let direct = chip.forward(&codes);
        let mut v = VirtualChip::new(die(8, 8, 1), 8, 8).unwrap();
        assert_eq!(v.forward(&codes).unwrap(), direct);
    }

    #[test]
    fn hidden_extension_matches_reference() {
        // L = 3N on a single-chunk input (Section VI-D: L=16 -> 128 case)
        let mut v = VirtualChip::new(die(8, 8, 3), 8, 24).unwrap();
        let codes = codes_pattern(8, 4);
        let got = v.forward(&codes).unwrap();
        let expect = reference_forward(&v.chip, &v.plan, &codes);
        assert_eq!(got, expect);
    }

    #[test]
    fn input_extension_matches_reference() {
        // d = 3k feeding the physical N neurons (leukemia-style d >> k)
        let mut v = VirtualChip::new(die(8, 8, 5), 24, 8).unwrap();
        let codes = codes_pattern(24, 6);
        let got = v.forward(&codes).unwrap();
        let expect = reference_forward(&v.chip, &v.plan, &codes);
        assert_eq!(got, expect);
    }

    #[test]
    fn combined_extension_matches_reference() {
        // ragged d and L exercising padding + both rotations at once
        let mut v = VirtualChip::new(die(8, 8, 7), 19, 21).unwrap();
        let codes = codes_pattern(19, 8);
        let got = v.forward(&codes).unwrap();
        let expect = reference_forward(&v.chip, &v.plan, &codes);
        assert_eq!(got, expect);
    }

    #[test]
    fn virtual_weights_cover_distinct_rotations() {
        // every hidden block must see a *different* row rotation — the
        // whole point of the reuse scheme (Fig. 11).
        let chip = die(4, 4, 9);
        let plan = RotationPlan::new(4, 4, 4, 16).unwrap();
        let t = chip.cfg.temp_k;
        let col0: Vec<Vec<u64>> = (0..4)
            .map(|m| {
                (0..4)
                    .map(|i| plan.virtual_weight(&chip.mismatch, i, m * 4, t).to_bits())
                    .collect()
            })
            .collect();
        for a in 0..4 {
            for b in a + 1..4 {
                assert_ne!(col0[a], col0[b], "blocks {a} and {b} reuse identical rows");
            }
        }
    }

    #[test]
    fn extension_books_physical_passes_on_ledger() {
        let mut v = VirtualChip::new(die(8, 8, 10), 24, 24).unwrap();
        let codes = codes_pattern(24, 11);
        v.chip.reset_ledger();
        let _ = v.forward(&codes).unwrap();
        assert_eq!(v.chip.ledger.conversions as usize, v.plan.passes());
    }

    #[test]
    fn input_register_rotation_round_trips() {
        // k Rotation_Control pulses are a full period: the registers
        // must return to the loaded vector exactly (Fig. 12 circuit)
        let mut chip = die(8, 8, 20);
        let codes = codes_pattern(8, 21);
        chip.load_input(&codes);
        for _ in 0..8 {
            chip.input_regs.rotate();
        }
        assert_eq!(chip.input_regs.read(), &codes[..]);
        assert_eq!(chip.input_regs.rotation, 8);
        // a single rotation shifts left by one (channel i sees i+1)
        chip.load_input(&codes);
        chip.input_regs.rotate();
        let got = chip.input_regs.read().to_vec();
        let mut expect = codes.clone();
        expect.rotate_left(1);
        assert_eq!(got, expect);
    }

    #[test]
    fn output_bank_rotation_round_trips() {
        // N CLK_r pulses restore the latched counts (Fig. 13 bank)
        let mut bank = crate::chip::spi::OutputBank::new(6);
        let counts: Vec<u32> = vec![5, 9, 0, 31, 2, 17];
        bank.latch(&counts);
        for _ in 0..6 {
            bank.clk_r();
        }
        assert_eq!(bank.peek_rot(), &counts[..]);
        // accumulate twice without rotation: acc = 2x counts
        bank.clk_a();
        bank.clk_a();
        let doubled: Vec<u32> = counts.iter().map(|&c| 2 * c).collect();
        assert_eq!(bank.read_and_clear(), doubled);
        assert!(bank.peek_acc().iter().all(|&a| a == 0));
    }

    #[test]
    fn virtual_weight_identity_block_matches_physical() {
        // block m=0, chunk c=0 applies no rotation: the virtual weight
        // must be exactly the physical mismatch weight
        let chip = die(6, 6, 22);
        let plan = RotationPlan::new(6, 6, 6, 6).unwrap();
        let t = chip.cfg.temp_k;
        for i in 0..6 {
            for j in 0..6 {
                assert_eq!(
                    plan.virtual_weight(&chip.mismatch, i, j, t).to_bits(),
                    chip.mismatch.weight(i, j, t).to_bits(),
                    "({i},{j})"
                );
            }
        }
    }

    #[test]
    fn more_virtual_neurons_do_not_repeat_columns() {
        // sanity on the feature expansion: virtual H columns should not
        // be bitwise duplicates across blocks for a generic input
        let mut v = VirtualChip::new(die(8, 8, 12), 8, 16).unwrap();
        let codes = codes_pattern(8, 13);
        let h = v.forward(&codes).unwrap();
        assert_ne!(&h[0..8], &h[8..16]);
    }

    #[test]
    fn dimension_mismatch_is_an_error_not_a_panic() {
        let mut v = VirtualChip::new(die(8, 8, 14), 16, 16).unwrap();
        assert!(v.forward(&codes_pattern(8, 15)).is_err());
        assert!(v.forward_features(&[0.0; 3]).is_err());
        let mut p = ServeChip::physical(die(8, 8, 14));
        assert!(p.forward(&codes_pattern(5, 16)).is_err());
        assert!(p.forward_features(&[0.0; 9]).is_err());
    }

    #[test]
    fn serve_chip_picks_physical_fast_path_for_trivial_plans() {
        let s = ServeChip::new(die(8, 8, 17), 8, 8).unwrap();
        assert!(s.is_physical());
        assert_eq!(s.passes(), 1);
        assert!(s.plan().is_none());
        let v = ServeChip::new(die(8, 8, 17), 24, 24).unwrap();
        assert!(!v.is_physical());
        assert_eq!(v.passes(), 9);
        assert_eq!((v.input_dim(), v.hidden_dim()), (24, 24));
        assert!(ServeChip::new(die(8, 8, 17), 8 * 8 + 1, 8).is_err());
    }

    #[test]
    fn serve_chip_forward_matches_virtual_chip() {
        let codes = codes_pattern(24, 18);
        let mut v = VirtualChip::new(die(8, 8, 19), 24, 16).unwrap();
        let mut s = ServeChip::new(die(8, 8, 19), 24, 16).unwrap();
        assert_eq!(s.forward(&codes).unwrap(), v.forward(&codes).unwrap());
    }

    #[test]
    fn serve_hidden_trains_like_chip_hidden_on_physical_dies() {
        // the coordinator's training view must be bit-identical to the
        // pre-existing ChipHidden path when the die serves physically
        let x: Vec<f64> = (0..8).map(|i| i as f64 / 8.0 - 0.4).collect();
        let mut a = crate::elm::ChipHidden::new(die(8, 8, 20));
        let mut b = ServeHidden { die: ServeChip::physical(die(8, 8, 20)), normalize: false };
        assert_eq!(
            crate::elm::train::HiddenLayer::transform(&mut a, &x),
            crate::elm::train::HiddenLayer::transform(&mut b, &x)
        );
        let mut an = crate::elm::ChipHidden::normalized(die(8, 8, 20));
        let mut bn = ServeHidden { die: ServeChip::physical(die(8, 8, 20)), normalize: true };
        assert_eq!(
            crate::elm::train::HiddenLayer::transform(&mut an, &x),
            crate::elm::train::HiddenLayer::transform(&mut bn, &x)
        );
    }
}
