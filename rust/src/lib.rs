//! # velm — VLSI Extreme Learning Machine, full-stack reproduction
//!
//! Reproduction of *"VLSI Extreme Learning Machine: A Design Space
//! Exploration"* (Yao & Basu, 2016) as a three-layer Rust + JAX/Pallas
//! stack: a behavioural model of the mixed-signal chip ([`chip`]), the
//! ELM algorithm layer ([`elm`]), the Section V dimension-extension
//! technique ([`extension`]), a PJRT runtime executing the AOT-compiled
//! JAX model ([`runtime`]), a serving coordinator ([`coordinator`]),
//! a multi-tenant model registry ([`registry`]) that lets many
//! workloads share one die fleet's hidden layer, a typed, versioned
//! serving protocol ([`protocol`]) with a client SDK ([`client`]), and
//! a traffic-adaptive power/accuracy governor ([`governor`]) that
//! moves dies along the tuned Pareto front at runtime.
//! Concurrency is funnelled through the [`sync`] facade so the
//! model checker ([`testing::model`]) and the `velm lint` invariant
//! scanner ([`analysis`]) can vouch for the lock-free hot paths.
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod analysis;
pub mod bench;
pub mod chip;
pub mod cli;
pub mod client;
pub mod config;
pub mod coordinator;
pub mod datasets;
pub mod dse;
pub mod elm;
pub mod extension;
pub mod fleet;
pub mod governor;
pub mod loadgen;
pub mod protocol;
pub mod registry;
pub mod runtime;
pub mod sync;
pub mod testing;
pub mod util;
