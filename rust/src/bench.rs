//! Bench harness for the `harness = false` bench targets (no criterion in
//! the offline vendor set): warmup + adaptive iteration timing with
//! median/MAD reporting, plus aligned table printing for the
//! paper-vs-measured rows every bench emits.

use std::time::Instant;

use crate::util::stats;

/// Timing result of one benchmark.
#[derive(Clone, Debug)]
pub struct Timing {
    pub name: String,
    pub iters: u64,
    pub median_s: f64,
    pub mad_s: f64,
    pub min_s: f64,
}

impl Timing {
    pub fn per_iter_pretty(&self) -> String {
        format_duration(self.median_s)
    }
}

/// Human-friendly duration.
pub fn format_duration(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Time `f`, choosing an iteration count so total sampling takes roughly
/// `budget_s`. Returns per-iteration stats over >= 5 samples.
pub fn bench<F: FnMut()>(name: &str, budget_s: f64, mut f: F) -> Timing {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let samples = 5usize.max((budget_s / once).min(50.0) as usize);
    let inner = ((budget_s / samples as f64 / once).ceil() as u64).max(1);
    let mut per_iter = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..inner {
            f();
        }
        per_iter.push(t.elapsed().as_secs_f64() / inner as f64);
    }
    let timing = Timing {
        name: name.to_string(),
        iters: inner * samples as u64,
        median_s: stats::median(&per_iter),
        mad_s: stats::mad(&per_iter),
        min_s: per_iter.iter().cloned().fold(f64::MAX, f64::min),
    };
    println!(
        "bench {:40} {:>12}/iter  (mad {:>10}, min {:>10}, n={})",
        timing.name,
        timing.per_iter_pretty(),
        format_duration(timing.mad_s),
        format_duration(timing.min_s),
        timing.iters
    );
    timing
}

/// Aligned table printer used by every figure/table bench.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("| {:width$} ", c, width = widths[i]));
            }
            s.push('|');
            s
        };
        let sep: String = widths
            .iter()
            .map(|w| format!("|{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "|";
        println!("{}", line(&self.headers));
        println!("{sep}");
        for r in &self.rows {
            println!("{}", line(r));
        }
    }
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Paper-vs-measured footnote formatting.
pub fn paper_vs(measured: f64, paper: f64, unit: &str) -> String {
    format!("measured {measured:.4} {unit} (paper: {paper:.4} {unit}, ratio {:.2})",
        if paper != 0.0 { measured / paper } else { f64::NAN })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let t = bench("noop-ish", 0.05, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(t.median_s > 0.0);
        assert!(t.min_s <= t.median_s * 1.5);
        assert!(t.iters >= 5);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(format_duration(2.0), "2.000 s");
        assert_eq!(format_duration(2e-3), "2.000 ms");
        assert_eq!(format_duration(2e-6), "2.000 us");
        assert_eq!(format_duration(2e-9), "2.0 ns");
    }

    #[test]
    fn table_alignment_roundtrip() {
        let mut t = Table::new(&["a", "bbbb"]);
        t.rowf(&["1", "2"]);
        t.row(&["x".to_string(), "yy".to_string()]);
        t.print(); // visual; just must not panic
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a"]);
        t.rowf(&["1", "2"]);
    }
}
