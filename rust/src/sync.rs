//! Synchronization facade for every lock-free hot-path structure.
//!
//! All cross-thread atomics and mutexes in this crate are imported from
//! here, never from `std::sync` directly (`velm lint` enforces this —
//! see [`crate::analysis`] and DESIGN.md §18). In a normal build the
//! facade is a zero-cost re-export of `std::sync`. Under
//! `--features model` it re-exports the deterministic modeled
//! implementation in [`crate::testing::model::sync`], whose types wrap
//! the std ones but announce every operation to the bounded-preemption
//! model checker, letting `tests/model_checker.rs` enumerate thread
//! interleavings exhaustively.
//!
//! Rules (mechanically checked by `velm lint`):
//!
//! - import `AtomicBool`/`AtomicU8`/`AtomicU64`/`AtomicUsize`,
//!   `Ordering`, `Mutex`, and `MutexGuard` from `crate::sync`;
//! - `std::sync::{mpsc, Arc, Condvar}` and the error types below stay
//!   direct std imports (they need no modeling: `Arc` is immutable
//!   plumbing, channels are linearizable FIFOs driven from one side in
//!   every checked scenario);
//! - every `Ordering::Relaxed` at a cross-thread site carries a
//!   `// relaxed-ok:` justification comment.

#[cfg(not(feature = "model"))]
mod imp {
    pub use std::sync::atomic::{AtomicBool, AtomicU8, AtomicU64, AtomicUsize, Ordering};
    pub use std::sync::{Mutex, MutexGuard};
}

#[cfg(feature = "model")]
mod imp {
    pub use crate::testing::model::sync::{
        AtomicBool, AtomicU8, AtomicU64, AtomicUsize, Mutex, MutexGuard,
    };
    pub use std::sync::atomic::Ordering;
}

pub use imp::*;

// The lock error types are std's in both configurations: the modeled
// Mutex bottoms out on a std Mutex and passes its poison state through
// unchanged, so recovery code (`PoisonError::into_inner`) is identical
// under test and in production.
pub use std::sync::{LockResult, PoisonError, TryLockError, TryLockResult};
