//! Multi-tenant model registry (DESIGN.md §14): one die fleet, many
//! output heads.
//!
//! The paper's first stage — the σVT-mismatch random projection — is
//! task-agnostic; only the trained second stage is task-specific
//! (Section II; the same observation drives the shared random-feature
//! arrays of arXiv:1512.07783 and the per-task second-stage retraining
//! of arXiv:1509.07450). This module exploits that: every physical die
//! keeps its one hidden-layer computation, and any number of *tenants*
//! — (name, task, training set) triples — install their own output
//! heads on top of it. Serving a new workload is a `REGISTER`, not a
//! new fleet.
//!
//! Split of responsibility:
//!   * [`TenantSpec`] — immutable description of one tenant (task kind,
//!     training set, solver hyperparameters), shared as `Arc` between
//!     the coordinator and every worker.
//!   * [`TenantEntry`] — the per-die trained state: one quantised
//!     [`SecondStage`] per output head plus the shared-P OS-ELM solver
//!     ([`MultiOnlineElm`]) for incremental updates. Owned by the
//!     worker thread that owns the die, so head resolution on the
//!     serve path reads thread-local data — no lock, no atomics.
//!   * [`ModelRegistry`] — the coordinator-side directory (name →
//!     [`TenantInfo`]): spec, per-die train scores, per-tenant metrics.
//!     Behind a mutex, but only on the cold path (register/unregister/
//!     listing and the submit-side tenant lookup); workers never touch
//!     it. Updates reach workers as control messages on the same
//!     ordered channel as probes and refits.
//!
//! Training a tenant is chip-in-the-loop and *shared-H*: the tenant's
//! training set is driven through the die once, and every head of that
//! tenant (10 one-vs-all columns for a digits tenant, 1 for binary or
//! regression) is solved from that single H via one Cholesky
//! factorisation (`elm::train::solve_heads`) — the chip is never
//! re-driven per head.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::coordinator::metrics::TenantMetrics;
use crate::elm::online::MultiOnlineElm;
use crate::elm::secondstage::SecondStage;
use crate::extension::ServeChip;
use crate::util::mat::Mat;

/// What a tenant's head(s) compute from the shared hidden layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// `classes == 2`: one ±1 head, label = sign. `classes > 2`:
    /// one-vs-all heads, label = argmax (Section II's "each output one
    /// by one" extension).
    Classification { classes: usize },
    /// One head, raw score (rescaled to training units).
    Regression,
}

impl Task {
    /// Output heads this task solves over the shared H.
    pub fn heads(&self) -> usize {
        match *self {
            Task::Classification { classes } => {
                if classes <= 2 {
                    1
                } else {
                    classes
                }
            }
            Task::Regression => 1,
        }
    }

    pub fn is_classification(&self) -> bool {
        matches!(self, Task::Classification { .. })
    }
}

impl std::fmt::Display for Task {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            Task::Classification { classes } => write!(f, "classification/{classes}"),
            Task::Regression => write!(f, "regression"),
        }
    }
}

/// Immutable description of one tenant, shared (`Arc`) between the
/// coordinator's registry and every worker's tenant table. Workers keep
/// it so a die refit can re-solve *all* registered heads
/// chip-in-the-loop without asking the coordinator for data.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    pub name: String,
    pub task: Task,
    /// Training features in [-1, 1]^d (d = the fleet's served dim).
    pub xs: Vec<Vec<f64>>,
    /// Targets, one column per head: ±1 for classification columns,
    /// raw floats for regression.
    pub targets: Mat,
    pub lambda: f64,
    pub beta_bits: u32,
    /// Accuracy SLO the governor holds (DESIGN.md §17): worst train
    /// score the tenant tolerates (classification: error rate;
    /// regression: RMSE in target units). `None` = the governor's
    /// fleet-wide default applies.
    pub slo_max_err: Option<f64>,
    /// Latency SLO: p99 end-to-end budget in microseconds. `None` =
    /// the governor's fleet-wide default applies.
    pub slo_p99_us: Option<u64>,
}

impl TenantSpec {
    /// Binary classification tenant (±1 targets).
    pub fn classification(
        name: &str,
        xs: Vec<Vec<f64>>,
        ys: &[f64],
        lambda: f64,
        beta_bits: u32,
    ) -> Result<Self, String> {
        if ys.iter().any(|&y| (y - 1.0).abs() > 1e-9 && (y + 1.0).abs() > 1e-9) {
            return Err(format!("tenant {name}: binary targets must be ±1"));
        }
        let targets = Mat { rows: ys.len(), cols: 1, data: ys.to_vec() };
        let spec = TenantSpec {
            name: name.to_string(),
            task: Task::Classification { classes: 2 },
            xs,
            targets,
            lambda,
            beta_bits,
            slo_max_err: None,
            slo_p99_us: None,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Multi-class tenant: `classes` one-vs-all heads over one H.
    pub fn multiclass(
        name: &str,
        xs: Vec<Vec<f64>>,
        labels: &[usize],
        classes: usize,
        lambda: f64,
        beta_bits: u32,
    ) -> Result<Self, String> {
        if classes < 3 || classes > 127 {
            return Err(format!(
                "tenant {name}: {classes} classes out of range 3..=127 \
                 (use TenantSpec::classification for binary tasks)"
            ));
        }
        if let Some(&bad) = labels.iter().find(|&&c| c >= classes) {
            return Err(format!("tenant {name}: label {bad} out of range for {classes} classes"));
        }
        let targets = Mat::from_fn(labels.len(), classes, |i, c| {
            if labels[i] == c {
                1.0
            } else {
                -1.0
            }
        });
        let spec = TenantSpec {
            name: name.to_string(),
            task: Task::Classification { classes },
            xs,
            targets,
            lambda,
            beta_bits,
            slo_max_err: None,
            slo_p99_us: None,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Regression tenant (raw float targets).
    pub fn regression(
        name: &str,
        xs: Vec<Vec<f64>>,
        ys: &[f64],
        lambda: f64,
        beta_bits: u32,
    ) -> Result<Self, String> {
        let targets = Mat { rows: ys.len(), cols: 1, data: ys.to_vec() };
        let spec = TenantSpec {
            name: name.to_string(),
            task: Task::Regression,
            xs,
            targets,
            lambda,
            beta_bits,
            slo_max_err: None,
            slo_p99_us: None,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Build a tenant from a named dataset — the `REGISTER` command and
    /// `velm serve --tenant` surface. `expect_d` is the fleet's served
    /// input dimension; a mismatched dataset is refused here, before
    /// any chip time is spent.
    ///
    /// Names: `digits` (10-class images), `digits-binary` (digit < 5),
    /// `brightness` (regression: mean pixel intensity of digit images —
    /// a second task over the *same* feature space as `digits`), `sinc`
    /// (regression), plus every other `datasets::synth::by_name` set as
    /// binary classification.
    pub fn from_dataset(
        tenant: &str,
        dataset: &str,
        seed: u64,
        expect_d: usize,
    ) -> Result<Self, String> {
        let (spec, d) = match dataset {
            "digits" => {
                let (ds, labels, _) = crate::datasets::digits::digits(400, 1, seed);
                let d = ds.d();
                (
                    TenantSpec::multiclass(tenant, ds.train_x, &labels, 10, 1e-2, 10)?,
                    d,
                )
            }
            "digits-binary" => {
                let (ds, labels, _) = crate::datasets::digits::digits(400, 1, seed);
                let d = ds.d();
                let ys: Vec<f64> =
                    labels.iter().map(|&c| if c < 5 { 1.0 } else { -1.0 }).collect();
                (
                    TenantSpec::classification(tenant, ds.train_x, &ys, 1e-2, 10)?,
                    d,
                )
            }
            "brightness" => {
                let (ds, _, _) = crate::datasets::digits::digits(400, 1, seed ^ 0xB516);
                let d = ds.d();
                let ys: Vec<f64> = ds
                    .train_x
                    .iter()
                    .map(|x| x.iter().sum::<f64>() / x.len() as f64)
                    .collect();
                (
                    TenantSpec::regression(tenant, ds.train_x, &ys, 1e-2, 10)?,
                    d,
                )
            }
            "sinc" => {
                let ds = crate::datasets::synth::by_name("sinc", seed)
                    .expect("sinc is a named dataset");
                let d = ds.d();
                (
                    TenantSpec::regression(tenant, ds.train_x, &ds.train_y, 1e-2, 10)?,
                    d,
                )
            }
            other => {
                let ds = crate::datasets::synth::by_name(other, seed)
                    .ok_or_else(|| format!("unknown dataset {other}"))?;
                let d = ds.d();
                (
                    TenantSpec::classification(tenant, ds.train_x, &ds.train_y, 1e-2, 10)?,
                    d,
                )
            }
        };
        if d != expect_d {
            return Err(format!(
                "dataset {dataset} has dimension {d}, fleet serves {expect_d}"
            ));
        }
        Ok(spec)
    }

    /// Attach per-tenant SLO targets (builder style; `None` keeps the
    /// governor's fleet-wide defaults).
    pub fn with_slo(mut self, max_err: Option<f64>, p99_us: Option<u64>) -> Self {
        self.slo_max_err = max_err;
        self.slo_p99_us = p99_us;
        self
    }

    /// Internal consistency: non-empty, rectangular, targets aligned.
    pub fn validate(&self) -> Result<(), String> {
        if self.xs.is_empty() {
            return Err(format!("tenant {}: empty training set", self.name));
        }
        let d = self.xs[0].len();
        if self.xs.iter().any(|x| x.len() != d) {
            return Err(format!("tenant {}: ragged training set", self.name));
        }
        if self.targets.rows != self.xs.len() {
            return Err(format!(
                "tenant {}: {} samples but {} target rows",
                self.name,
                self.xs.len(),
                self.targets.rows
            ));
        }
        if self.targets.cols != self.task.heads() {
            return Err(format!(
                "tenant {}: task {} wants {} target columns, got {}",
                self.name,
                self.task,
                self.task.heads(),
                self.targets.cols
            ));
        }
        if let Task::Classification { classes } = self.task {
            if classes > 127 {
                return Err(format!("tenant {}: {classes} classes exceed the i8 label", self.name));
            }
        }
        Ok(())
    }

    /// Input dimension the tenant's requests must carry.
    pub fn d(&self) -> usize {
        self.xs.first().map_or(0, |x| x.len())
    }

    /// Train-set score of predictions `p_i = rls.predict(h_i)` against
    /// this spec's targets: error rate for classification, RMSE for
    /// regression (lower is better for both).
    pub fn score_predictions(&self, h: &Mat, rls: &MultiOnlineElm) -> f64 {
        let n = h.rows.max(1);
        match self.task {
            Task::Regression => {
                let mut acc = 0.0;
                for i in 0..h.rows {
                    let p = rls.predict_head(h.row(i), 0);
                    let d = p - self.targets.get(i, 0);
                    acc += d * d;
                }
                (acc / n as f64).sqrt()
            }
            Task::Classification { classes } if classes <= 2 => {
                let mut wrong = 0usize;
                for i in 0..h.rows {
                    let p = rls.predict_head(h.row(i), 0);
                    if (p.signum() - self.targets.get(i, 0).signum()).abs() > 1e-9 {
                        wrong += 1;
                    }
                }
                wrong as f64 / n as f64
            }
            Task::Classification { .. } => {
                let mut wrong = 0usize;
                for i in 0..h.rows {
                    let p = rls.predict(h.row(i));
                    let pred = argmax(&p);
                    let truth = argmax(self.targets.row(i));
                    if pred != truth {
                        wrong += 1;
                    }
                }
                wrong as f64 / n as f64
            }
        }
    }
}

fn argmax(v: &[f64]) -> usize {
    let mut best = (0usize, f64::NEG_INFINITY);
    for (i, &x) in v.iter().enumerate() {
        if x > best.1 {
            best = (i, x);
        }
    }
    best.0
}

/// Per-die trained state for one tenant, owned by the worker thread
/// that owns the die (lock-free head resolution on the serve path).
pub struct TenantEntry {
    pub spec: Arc<TenantSpec>,
    /// One quantised second stage per head, rebuilt from `rls.betas`
    /// after every solve or OS-ELM update.
    pub heads: Vec<SecondStage>,
    /// Shared-P recursive solver: OS-ELM updates stream labelled
    /// samples into all heads at O(L²) per sample, one P for the lot.
    pub rls: MultiOnlineElm,
}

impl TenantEntry {
    /// Re-quantise the deployed heads from the float RLS state.
    pub fn rebuild_heads(&mut self, normalize: bool) {
        self.heads = self
            .rls
            .betas
            .iter()
            .map(|b| SecondStage::new(b, self.spec.beta_bits, normalize))
            .collect();
    }

    /// Score one served row of raw counter outputs. `scale` is the
    /// counter-cap activation scaling (1/2^b) that training applied to
    /// H, so returned scores are in training units — sign and argmax
    /// are invariant, and regression outputs land in target units.
    pub fn score_row(&self, h: &[u32], codes_sum: f64, scale: f64) -> (i8, f64) {
        match self.spec.task {
            Task::Regression => {
                let s = self.heads[0].score(h, codes_sum) * scale;
                (0, s)
            }
            Task::Classification { classes } if classes <= 2 => {
                let s = self.heads[0].score(h, codes_sum) * scale;
                (if s >= 0.0 { 1 } else { -1 }, s)
            }
            Task::Classification { .. } => {
                let mut best = (0usize, f64::NEG_INFINITY);
                for (c, head) in self.heads.iter().enumerate() {
                    let s = head.score(h, codes_sum);
                    if s > best.1 {
                        best = (c, s);
                    }
                }
                (best.0 as i8, best.1 * scale)
            }
        }
    }

    /// OS-ELM incremental update: absorb one (hidden row, target row)
    /// pair into every head and redeploy the quantised stages.
    pub fn absorb(&mut self, h_row: &[f64], targets: &[f64]) -> Result<(), String> {
        if targets.len() != self.rls.betas.len() {
            return Err(format!(
                "tenant {}: update carries {} targets, task has {} heads",
                self.spec.name,
                targets.len(),
                self.rls.betas.len()
            ));
        }
        let normalize = self.heads.first().is_some_and(|h| h.normalize);
        self.rls.update(h_row, targets);
        self.rebuild_heads(normalize);
        Ok(())
    }
}

/// Chip-in-the-loop tenant training on one die: drive the tenant's
/// training set through the die **once** (through the rotation plan on
/// a virtual die), then solve every head of the tenant from that single
/// H via the shared-P batch init of [`MultiOnlineElm`] — one Cholesky,
/// no per-head chip passes. Returns the trained entry plus its
/// train-set score (error rate / RMSE) on this die.
pub fn fit_on_die(
    die: &mut ServeChip,
    normalize: bool,
    spec: &Arc<TenantSpec>,
) -> Result<(TenantEntry, f64), String> {
    spec.validate()?;
    if spec.d() != die.input_dim() {
        return Err(format!(
            "tenant {}: training dimension {} != served dimension {}",
            spec.name,
            spec.d(),
            die.input_dim()
        ));
    }
    let rows: Vec<Vec<f64>> = spec
        .xs
        .iter()
        .map(|x| {
            die.assemble_row(x, normalize)
                .map_err(|e| format!("tenant {}: {e}", spec.name))
        })
        .collect::<Result<_, String>>()?;
    let h = Mat::from_rows(&rows);
    let rls = MultiOnlineElm::from_batch(&h, &spec.targets, spec.lambda)?;
    let score = spec.score_predictions(&h, &rls);
    let mut entry = TenantEntry { spec: Arc::clone(spec), heads: Vec::new(), rls };
    entry.rebuild_heads(normalize);
    Ok((entry, score))
}

/// Coordinator-side record of a registered tenant.
pub struct TenantInfo {
    pub spec: Arc<TenantSpec>,
    /// The name as a cheap shared tag for request routing.
    pub tag: Arc<str>,
    /// Chip-in-the-loop train score per die (error rate / RMSE).
    pub die_scores: Vec<f64>,
    pub metrics: Arc<TenantMetrics>,
}

/// The coordinator's tenant directory. Cold path only: workers resolve
/// heads from their own tables; this map backs REGISTER / UNREGISTER /
/// MODELS and the submit-side tenant lookup.
#[derive(Default)]
pub struct ModelRegistry {
    tenants: BTreeMap<String, TenantInfo>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        ModelRegistry::default()
    }

    pub fn contains(&self, name: &str) -> bool {
        self.tenants.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&TenantInfo> {
        self.tenants.get(name)
    }

    pub fn insert(&mut self, info: TenantInfo) {
        self.tenants.insert(info.spec.name.clone(), info);
    }

    pub fn remove(&mut self, name: &str) -> Option<TenantInfo> {
        self.tenants.remove(name)
    }

    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &TenantInfo)> {
        self.tenants.iter()
    }

    /// One-line listing for the `MODELS` command. The train score is
    /// the live gauge from [`TenantMetrics`]: the across-dies mean at
    /// registration, refreshed with post-refit scores when drift
    /// recovery re-solves the heads (`die_scores` keeps the per-die
    /// registration-time values).
    pub fn listing(&self) -> String {
        self.tenants
            .values()
            .map(|info| {
                format!(
                    "{} task={} heads={} dies={} train_score={:.4}",
                    info.spec.name,
                    info.spec.task,
                    info.spec.task.heads(),
                    info.die_scores.len(),
                    info.metrics.score()
                )
            })
            .collect::<Vec<_>>()
            .join("; ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::ChipModel;
    use crate::config::ChipConfig;
    use crate::util::prng::Prng;

    fn die(seed: u64, d: usize, l: usize) -> ServeChip {
        let cfg = ChipConfig::default().with_dims(d, l).with_b(10);
        ServeChip::physical(ChipModel::fabricate(cfg, seed))
    }

    fn blobs(seed: u64, n: usize, d: usize) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Prng::new(seed);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..n {
            let y = if rng.bool(0.5) { 1.0 } else { -1.0 };
            xs.push(
                (0..d)
                    .map(|_| (0.4 * y + rng.normal(0.0, 0.15)).clamp(-1.0, 1.0))
                    .collect::<Vec<f64>>(),
            );
            ys.push(y);
        }
        (xs, ys)
    }

    #[test]
    fn task_head_counts() {
        assert_eq!(Task::Classification { classes: 2 }.heads(), 1);
        assert_eq!(Task::Classification { classes: 10 }.heads(), 10);
        assert_eq!(Task::Regression.heads(), 1);
        assert_eq!(Task::Regression.to_string(), "regression");
        assert_eq!(
            Task::Classification { classes: 10 }.to_string(),
            "classification/10"
        );
    }

    #[test]
    fn spec_validation_catches_shape_bugs() {
        let (xs, ys) = blobs(1, 20, 4);
        assert!(TenantSpec::classification("t", xs.clone(), &ys, 1e-2, 10).is_ok());
        // non-±1 binary targets
        assert!(TenantSpec::classification("t", xs.clone(), &[0.5; 20], 1e-2, 10).is_err());
        // bad label range
        let labels = vec![3usize; 20];
        assert!(TenantSpec::multiclass("t", xs.clone(), &labels, 3, 1e-2, 10).is_err());
        // empty training set
        assert!(TenantSpec::regression("t", vec![], &[], 1e-2, 10).is_err());
        // ragged rows
        let mut ragged = xs;
        ragged[3] = vec![0.0; 7];
        assert!(TenantSpec::classification("t", ragged, &ys, 1e-2, 10).is_err());
    }

    #[test]
    fn binary_tenant_fits_and_scores_on_a_die() {
        let mut d = die(3, 6, 48);
        let (xs, ys) = blobs(4, 160, 6);
        let spec =
            Arc::new(TenantSpec::classification("blobs", xs.clone(), &ys, 1e-2, 10).unwrap());
        let (entry, score) = fit_on_die(&mut d, false, &spec).unwrap();
        assert!(score < 0.1, "train err {score}");
        assert_eq!(entry.heads.len(), 1);
        // served path agrees with training labels on most samples
        let cfg = d.chip().cfg.clone();
        let scale = 1.0 / cfg.cap() as f64;
        let mut correct = 0;
        for (x, &y) in xs.iter().zip(&ys) {
            let codes = crate::chip::dac::features_to_codes(x, &cfg);
            let h = d.forward(&codes).unwrap();
            let (label, _) =
                entry.score_row(&h, crate::elm::secondstage::codes_sum(&codes), scale);
            if (label as f64 - y).abs() < 1e-9 {
                correct += 1;
            }
        }
        assert!(correct >= 140, "served path agrees on {correct}/160");
    }

    #[test]
    fn multiclass_tenant_shares_one_h_across_heads() {
        let mut d = die(5, 6, 64);
        let mut rng = Prng::new(6);
        // three gaussian blobs at distinct centers
        let centers = [[0.5, 0.5], [-0.5, 0.5], [0.0, -0.6]];
        let mut xs = Vec::new();
        let mut labels = Vec::new();
        for _ in 0..180 {
            let c = rng.usize(3);
            let mut x = vec![0.0; 6];
            x[0] = (centers[c][0] + rng.normal(0.0, 0.12)).clamp(-1.0, 1.0);
            x[1] = (centers[c][1] + rng.normal(0.0, 0.12)).clamp(-1.0, 1.0);
            xs.push(x);
            labels.push(c);
        }
        let spec =
            Arc::new(TenantSpec::multiclass("tri", xs, &labels, 3, 1e-2, 10).unwrap());
        let conv_before = d.chip().ledger.conversions;
        let (entry, score) = fit_on_die(&mut d, false, &spec).unwrap();
        // shared H: exactly one conversion per training sample, not per head
        assert_eq!(d.chip().ledger.conversions - conv_before, 180);
        assert_eq!(entry.heads.len(), 3);
        assert!(score < 0.15, "train err {score}");
    }

    #[test]
    fn regression_tenant_scores_in_target_units() {
        let mut d = die(7, 4, 64);
        let mut rng = Prng::new(8);
        let xs: Vec<Vec<f64>> =
            (0..150).map(|_| (0..4).map(|_| rng.range(-1.0, 1.0)).collect()).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 0.5 * x[0] + 0.3 * x[1] * x[2]).collect();
        let spec = Arc::new(TenantSpec::regression("lin", xs.clone(), &ys, 1e-3, 12).unwrap());
        let (entry, rmse) = fit_on_die(&mut d, false, &spec).unwrap();
        assert!(rmse < 0.15, "train rmse {rmse}");
        // serve-path scores land near the raw targets (same units)
        let cfg = d.chip().cfg.clone();
        let scale = 1.0 / cfg.cap() as f64;
        let mut acc = 0.0;
        for (x, &y) in xs.iter().zip(&ys) {
            let codes = crate::chip::dac::features_to_codes(x, &cfg);
            let h = d.forward(&codes).unwrap();
            let (label, s) =
                entry.score_row(&h, crate::elm::secondstage::codes_sum(&codes), scale);
            assert_eq!(label, 0, "regression label is 0");
            acc += (s - y) * (s - y);
        }
        let served_rmse = (acc / xs.len() as f64).sqrt();
        assert!(served_rmse < 0.25, "served rmse {served_rmse}");
    }

    #[test]
    fn dimension_mismatch_is_refused_before_chip_time() {
        let mut d = die(9, 6, 24);
        let (xs, ys) = blobs(10, 30, 4); // d=4 against a 6-wide die
        let spec = Arc::new(TenantSpec::classification("bad", xs, &ys, 1e-2, 10).unwrap());
        let before = d.chip().ledger.conversions;
        assert!(fit_on_die(&mut d, false, &spec).is_err());
        assert_eq!(d.chip().ledger.conversions, before);
    }

    #[test]
    fn from_dataset_checks_dimensions_and_names() {
        assert!(TenantSpec::from_dataset("t", "nosuchset", 1, 8).is_err());
        // digits is 64-wide; a mismatched fleet dimension is refused
        assert!(TenantSpec::from_dataset("t", "digits", 1, 8).is_err());
        let spec = TenantSpec::from_dataset("t", "digits", 1, 64).unwrap();
        assert_eq!(spec.task, Task::Classification { classes: 10 });
        assert_eq!(spec.d(), 64);
        let b = TenantSpec::from_dataset("b", "brightness", 1, 64).unwrap();
        assert_eq!(b.task, Task::Regression);
        // brightness targets really are the mean pixel intensity
        for (x, i) in b.xs.iter().zip(0..b.targets.rows) {
            let mean = x.iter().sum::<f64>() / x.len() as f64;
            assert!((b.targets.get(i, 0) - mean).abs() < 1e-12);
        }
    }

    #[test]
    fn registry_directory_roundtrip() {
        let mut reg = ModelRegistry::new();
        assert!(reg.is_empty());
        let (xs, ys) = blobs(11, 10, 4);
        let spec = Arc::new(TenantSpec::classification("alpha", xs, &ys, 1e-2, 10).unwrap());
        let metrics = Arc::new(TenantMetrics::default());
        metrics.set_score(0.06);
        reg.insert(TenantInfo {
            spec: Arc::clone(&spec),
            tag: Arc::from("alpha"),
            die_scores: vec![0.05, 0.07],
            metrics,
        });
        assert!(reg.contains("alpha"));
        assert_eq!(reg.len(), 1);
        let listing = reg.listing();
        assert!(listing.contains("alpha"), "{listing}");
        assert!(listing.contains("classification/2"), "{listing}");
        assert!(listing.contains("train_score=0.0600"), "{listing}");
        assert!(reg.remove("alpha").is_some());
        assert!(!reg.contains("alpha"));
    }

    #[test]
    fn absorb_rejects_wrong_target_arity() {
        let mut d = die(12, 4, 16);
        let (xs, ys) = blobs(13, 40, 4);
        let spec = Arc::new(TenantSpec::classification("t", xs, &ys, 1e-2, 10).unwrap());
        let (mut entry, _) = fit_on_die(&mut d, false, &spec).unwrap();
        assert!(entry.absorb(&[0.1; 16], &[1.0, -1.0]).is_err());
        assert!(entry.absorb(&[0.1; 16], &[1.0]).is_ok());
    }
}
