//! Deterministic synthetic stand-ins for the paper's benchmark datasets.
//!
//! The image is offline, so the UCI sets of Table II cannot be fetched.
//! Each generator matches its dataset's shape (d, N_train, N_test, class
//! balance, feature style) and its *difficulty*: a random nonlinear
//! teacher (small tanh network) defines the decision boundary, and a
//! calibrated label-flip rate sets the achievable error floor so the
//! software-ELM baseline lands near the error the paper quotes from
//! [12]. The hardware-vs-software *gap* — the claim under test — is
//! independent of the exact data. DESIGN.md §4 records this substitution.

use super::Dataset;
use crate::util::prng::Prng;

/// A random teacher: y = sign(sum_m a_m tanh(w_m . x + b_m)).
struct Teacher {
    w: Vec<Vec<f64>>,
    b: Vec<f64>,
    a: Vec<f64>,
    thr: f64,
}

impl Teacher {
    fn new(d: usize, hidden: usize, rng: &mut Prng) -> Self {
        // weights scaled so the boundary is smooth enough for an ELM
        // with ~1e3 training samples to learn down to the flip floor
        let scale = 1.4 / (d as f64).sqrt();
        let w = (0..hidden)
            .map(|_| (0..d).map(|_| rng.normal(0.0, scale)).collect())
            .collect();
        let b = (0..hidden).map(|_| rng.normal(0.0, 0.5)).collect();
        let a = (0..hidden).map(|_| rng.normal(0.0, 1.0)).collect();
        Teacher { w, b, a, thr: 0.0 }
    }

    fn raw(&self, x: &[f64]) -> f64 {
        self.w
            .iter()
            .zip(&self.b)
            .zip(&self.a)
            .map(|((w, &b), &a)| {
                let z: f64 = w.iter().zip(x).map(|(wi, xi)| wi * xi).sum();
                a * (z + b).tanh()
            })
            .sum()
    }

    /// Calibrate the threshold to the median teacher output so classes
    /// balance, then label. Returns the raw-output std for margin tests.
    fn calibrate(&mut self, xs: &[Vec<f64>]) -> f64 {
        let raws: Vec<f64> = xs.iter().map(|x| self.raw(x)).collect();
        let mut sorted = raws.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        self.thr = sorted[sorted.len() / 2];
        crate::util::stats::std(&raws)
    }

}

/// Feature styles matching the source datasets.
#[derive(Clone, Copy, Debug)]
pub enum FeatureStyle {
    /// Continuous clinical-style features, U(-1,1) with mild correlation.
    Continuous,
    /// One-hot / binarised features in {-1, +1} (Adult's 123 binary dims).
    Binary { on_prob: f64 },
    /// Few informative continuous dims drowned in noise dims (leukemia).
    SparseInformative { informative: usize },
}

/// Generic two-class generator; see the named presets below.
/// Calls [`classification_margin`] with no margin band.
pub fn classification(
    name: &str,
    d: usize,
    n_train: usize,
    n_test: usize,
    style: FeatureStyle,
    flip: f64,
    seed: u64,
) -> Dataset {
    classification_margin(name, d, n_train, n_test, style, flip, 0.0, seed)
}

/// Two-class generator with a margin band: samples whose teacher output
/// falls within `margin` raw-stds of the threshold are rejected, which
/// controls how clean the achievable error floor is (brightdata is a
/// near-separable task; diabetes is heavily overlapped).
#[allow(clippy::too_many_arguments)]
pub fn classification_margin(
    name: &str,
    d: usize,
    n_train: usize,
    n_test: usize,
    style: FeatureStyle,
    flip: f64,
    margin: f64,
    seed: u64,
) -> Dataset {
    let mut rng = Prng::new(seed ^ 0xDA7A_5E7);
    let n = n_train + n_test;
    // Microarray-style data (tiny N, huge d) cannot carry a learnable
    // teacher boundary; it is a class-shift problem instead.
    if let FeatureStyle::SparseInformative { informative } = style {
        return sparse_shift(name, d, n_train, n_test, informative, flip, &mut rng);
    }
    let informative_dims = d;
    let sample_x = |rng: &mut Prng| -> Vec<f64> {
        match style {
            FeatureStyle::Continuous => (0..d).map(|_| rng.range(-1.0, 1.0)).collect(),
            FeatureStyle::Binary { on_prob } => (0..d)
                .map(|_| if rng.bool(on_prob) { 1.0 } else { -1.0 })
                .collect(),
            FeatureStyle::SparseInformative { .. } => (0..d)
                .map(|_| (rng.normal(0.0, 0.35)).clamp(-1.0, 1.0))
                .collect(),
        }
    };
    // calibrate the teacher on a pilot sample
    let pilot: Vec<Vec<f64>> = (0..512).map(|_| sample_x(&mut rng)).collect();
    let mut teacher = Teacher::new(informative_dims, 3, &mut rng);
    let pilot_proj: Vec<Vec<f64>> =
        pilot.iter().map(|x| x[..informative_dims].to_vec()).collect();
    let raw_std = teacher.calibrate(&pilot_proj).max(1e-9);
    // rejection-sample the margin band, then label with flips
    let mut xs: Vec<Vec<f64>> = Vec::with_capacity(n);
    let mut ys: Vec<f64> = Vec::with_capacity(n);
    while xs.len() < n {
        let x = sample_x(&mut rng);
        let raw = teacher.raw(&x[..informative_dims]);
        if (raw - teacher.thr).abs() < margin * raw_std {
            continue;
        }
        let y = if raw >= teacher.thr { 1.0 } else { -1.0 };
        ys.push(if rng.bool(flip) { -y } else { y });
        xs.push(x);
    }
    Dataset {
        name: name.to_string(),
        train_x: xs[..n_train].to_vec(),
        train_y: ys[..n_train].to_vec(),
        test_x: xs[n_train..].to_vec(),
        test_y: ys[n_train..].to_vec(),
    }
}

/// Leukemia-style generator: `informative` dims carry a class-dependent
/// mean shift (the biomarkers), the rest are noise. Labels flipped at
/// `flip` to set the error floor.
fn sparse_shift(
    name: &str,
    d: usize,
    n_train: usize,
    n_test: usize,
    informative: usize,
    flip: f64,
    rng: &mut Prng,
) -> Dataset {
    let informative = informative.min(d);
    // per-biomarker direction and strength
    let dirs: Vec<f64> = (0..informative)
        .map(|_| if rng.bool(0.5) { 1.0 } else { -1.0 })
        .collect();
    // real microarray biomarkers are strong relative to background
    let strength: Vec<f64> = (0..informative).map(|_| rng.range(0.5, 1.0)).collect();
    let n = n_train + n_test;
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for k in 0..n {
        let y = if k % 2 == 0 { 1.0 } else { -1.0 }; // balanced
        let x: Vec<f64> = (0..d)
            .map(|j| {
                let base = rng.normal(0.0, 0.10);
                let shift = if j < informative { y * dirs[j] * strength[j] } else { 0.0 };
                (base + shift).clamp(-1.0, 1.0)
            })
            .collect();
        ys.push(if rng.bool(flip) { -y } else { y });
        xs.push(x);
    }
    Dataset {
        name: name.to_string(),
        train_x: xs[..n_train].to_vec(),
        train_y: ys[..n_train].to_vec(),
        test_x: xs[n_train..].to_vec(),
        test_y: ys[n_train..].to_vec(),
    }
}

// --- Table II presets (shape-matched to the paper; flip rates calibrated
// --- so the software-ELM column lands near [12]'s numbers).

/// Pima Indians diabetes: d=8, 512/256, software error ~22%.
pub fn diabetes(seed: u64) -> Dataset {
    classification_margin("diabetes", 8, 512, 256, FeatureStyle::Continuous, 0.195, 0.55, seed)
}

/// Statlog Australian credit: d=14, 460/230, software error ~13.8%.
pub fn australian(seed: u64) -> Dataset {
    classification_margin(
        "australian", 14, 460, 230, FeatureStyle::Continuous, 0.105, 0.45, seed,
    )
}

/// Star/Galaxy bright: d=14, 1000/1462, software error ~0.7%
/// (a near-separable task: wide margin band, tiny flip rate).
pub fn brightdata(seed: u64) -> Dataset {
    classification_margin(
        "brightdata", 14, 1000, 1462, FeatureStyle::Continuous, 0.004, 0.55, seed,
    )
}

/// Adult: d=123 binarised, 4781/27780, software error ~15.4%.
pub fn adult(seed: u64) -> Dataset {
    classification_margin(
        "adult",
        123,
        4781,
        27780,
        FeatureStyle::Binary { on_prob: 0.12 },
        0.13,
        0.40,
        seed,
    )
}

/// Leukemia microarray: d=7129, 38/34, software error ~20% (Section VI-D).
pub fn leukemia(seed: u64) -> Dataset {
    classification(
        "leukemia",
        7129,
        38,
        34,
        FeatureStyle::SparseInformative { informative: 60 },
        0.12,
        seed,
    )
}

/// All Table II datasets in paper order.
pub fn table2_suite(seed: u64) -> Vec<Dataset> {
    vec![diabetes(seed), australian(seed + 1), brightdata(seed + 2), adult(seed + 3)]
}

/// By-name lookup for the CLI.
pub fn by_name(name: &str, seed: u64) -> Option<Dataset> {
    match name {
        "diabetes" => Some(diabetes(seed)),
        "australian" => Some(australian(seed)),
        "brightdata" => Some(brightdata(seed)),
        "adult" => Some(adult(seed)),
        "leukemia" => Some(leukemia(seed)),
        "sinc" => Some(sinc(5000, 1000, 0.2, seed)),
        _ => None,
    }
}

/// The Fig. 16 regression task: noisy samples of sinc on [-10, 10]
/// (sin(x)/x), gaussian noise sigma (paper: 0.2, 5000 train samples).
/// Features are x/10 in [-1,1]; *test* targets are the clean function, so
/// test RMSE is directly the paper's "error" against the underlying sinc.
pub fn sinc(n_train: usize, n_test: usize, noise_sigma: f64, seed: u64) -> Dataset {
    let mut rng = Prng::new(seed ^ 0x51AC);
    let f = |x: f64| if x.abs() < 1e-12 { 1.0 } else { x.sin() / x };
    let mut mk = |n: usize, noisy: bool| {
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        for k in 0..n {
            // deterministic grid + jitter covers the domain evenly
            let x = -10.0 + 20.0 * (k as f64 + rng.f64()) / n as f64;
            xs.push(vec![x / 10.0]);
            ys.push(f(x) + if noisy { rng.normal(0.0, noise_sigma) } else { 0.0 });
        }
        (xs, ys)
    };
    let (train_x, train_y) = mk(n_train, true);
    let (test_x, test_y) = mk(n_test, false);
    Dataset { name: "sinc".into(), train_x, train_y, test_x, test_y }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_shapes() {
        let d = diabetes(1);
        assert_eq!((d.d(), d.n_train(), d.n_test()), (8, 512, 256));
        let a = australian(1);
        assert_eq!((a.d(), a.n_train(), a.n_test()), (14, 460, 230));
        let b = brightdata(1);
        assert_eq!((b.d(), b.n_train(), b.n_test()), (14, 1000, 1462));
        let l = leukemia(1);
        assert_eq!((l.d(), l.n_train(), l.n_test()), (7129, 38, 34));
    }

    #[test]
    fn all_presets_validate() {
        for ds in [diabetes(2), australian(2), brightdata(2)] {
            ds.validate().unwrap();
        }
        leukemia(2).validate().unwrap();
    }

    #[test]
    fn adult_is_binary_and_validates() {
        let ds = adult(3).with_test_subsample(500, 1);
        ds.validate().unwrap();
        assert!(ds
            .train_x
            .iter()
            .all(|x| x.iter().all(|&v| v == 1.0 || v == -1.0)));
    }

    #[test]
    fn classes_roughly_balanced() {
        for ds in [diabetes(4), australian(5), brightdata(6)] {
            let frac = ds.train_pos_fraction();
            assert!((0.3..=0.7).contains(&frac), "{}: {frac}", ds.name);
        }
    }

    #[test]
    fn generators_deterministic() {
        let a = brightdata(7);
        let b = brightdata(7);
        assert_eq!(a.train_x, b.train_x);
        assert_eq!(a.train_y, b.train_y);
        let c = brightdata(8);
        assert_ne!(a.train_y, c.train_y);
    }

    #[test]
    fn sinc_test_targets_are_clean() {
        let ds = sinc(100, 50, 0.2, 9);
        for (x, &y) in ds.test_x.iter().zip(&ds.test_y) {
            let xv = x[0] * 10.0;
            let clean = if xv.abs() < 1e-12 { 1.0 } else { xv.sin() / xv };
            assert!((y - clean).abs() < 1e-12);
        }
        // train targets are noisy versions
        let noisy_dev: f64 = ds
            .train_x
            .iter()
            .zip(&ds.train_y)
            .map(|(x, &y)| {
                let xv = x[0] * 10.0;
                let clean = if xv.abs() < 1e-12 { 1.0 } else { xv.sin() / xv };
                (y - clean).abs()
            })
            .sum::<f64>()
            / 100.0;
        assert!(noisy_dev > 0.05, "train noise missing: {noisy_dev}");
    }

    #[test]
    fn by_name_covers_suite() {
        for n in ["diabetes", "australian", "brightdata", "adult", "leukemia", "sinc"] {
            assert!(by_name(n, 1).is_some(), "{n}");
        }
        assert!(by_name("nope", 1).is_none());
    }
}
