//! Synthetic multi-class digit images (MNIST stand-in for the paper's
//! stated future work: "apply this chip to classify multi-class image
//! datasets such as MNIST"). 8x8 grayscale prototypes per class with
//! elastic jitter, pixel noise and per-sample gain — small enough to run
//! through the 128-channel chip (64 pixels -> 64 channels, or 2x2
//! upsampled to exercise all 128+ via the extension path).

use super::Dataset;
use crate::util::prng::Prng;

pub const SIDE: usize = 8;
pub const DIM: usize = SIDE * SIDE;

/// Hand-drawn 8x8 prototypes for digits 0..=9 (0 = off, 1 = on).
const GLYPHS: [[u8; DIM]; 10] = [
    // 0
    [0,0,1,1,1,1,0,0, 0,1,1,0,0,1,1,0, 0,1,0,0,0,0,1,0, 0,1,0,0,0,0,1,0,
     0,1,0,0,0,0,1,0, 0,1,0,0,0,0,1,0, 0,1,1,0,0,1,1,0, 0,0,1,1,1,1,0,0],
    // 1
    [0,0,0,1,1,0,0,0, 0,0,1,1,1,0,0,0, 0,1,0,1,1,0,0,0, 0,0,0,1,1,0,0,0,
     0,0,0,1,1,0,0,0, 0,0,0,1,1,0,0,0, 0,0,0,1,1,0,0,0, 0,1,1,1,1,1,1,0],
    // 2
    [0,0,1,1,1,1,0,0, 0,1,0,0,0,1,1,0, 0,0,0,0,0,1,1,0, 0,0,0,0,1,1,0,0,
     0,0,0,1,1,0,0,0, 0,0,1,1,0,0,0,0, 0,1,1,0,0,0,0,0, 0,1,1,1,1,1,1,0],
    // 3
    [0,1,1,1,1,1,0,0, 0,0,0,0,0,1,1,0, 0,0,0,0,0,1,1,0, 0,0,1,1,1,1,0,0,
     0,0,0,0,0,1,1,0, 0,0,0,0,0,1,1,0, 0,0,0,0,0,1,1,0, 0,1,1,1,1,1,0,0],
    // 4
    [0,0,0,0,1,1,0,0, 0,0,0,1,1,1,0,0, 0,0,1,0,1,1,0,0, 0,1,0,0,1,1,0,0,
     0,1,1,1,1,1,1,0, 0,0,0,0,1,1,0,0, 0,0,0,0,1,1,0,0, 0,0,0,0,1,1,0,0],
    // 5
    [0,1,1,1,1,1,1,0, 0,1,1,0,0,0,0,0, 0,1,1,0,0,0,0,0, 0,1,1,1,1,1,0,0,
     0,0,0,0,0,1,1,0, 0,0,0,0,0,1,1,0, 0,1,0,0,0,1,1,0, 0,0,1,1,1,1,0,0],
    // 6
    [0,0,1,1,1,1,0,0, 0,1,1,0,0,0,0,0, 0,1,1,0,0,0,0,0, 0,1,1,1,1,1,0,0,
     0,1,1,0,0,1,1,0, 0,1,1,0,0,1,1,0, 0,1,1,0,0,1,1,0, 0,0,1,1,1,1,0,0],
    // 7
    [0,1,1,1,1,1,1,0, 0,0,0,0,0,1,1,0, 0,0,0,0,1,1,0,0, 0,0,0,0,1,1,0,0,
     0,0,0,1,1,0,0,0, 0,0,0,1,1,0,0,0, 0,0,1,1,0,0,0,0, 0,0,1,1,0,0,0,0],
    // 8
    [0,0,1,1,1,1,0,0, 0,1,1,0,0,1,1,0, 0,1,1,0,0,1,1,0, 0,0,1,1,1,1,0,0,
     0,1,1,0,0,1,1,0, 0,1,1,0,0,1,1,0, 0,1,1,0,0,1,1,0, 0,0,1,1,1,1,0,0],
    // 9
    [0,0,1,1,1,1,0,0, 0,1,1,0,0,1,1,0, 0,1,1,0,0,1,1,0, 0,0,1,1,1,1,1,0,
     0,0,0,0,0,1,1,0, 0,0,0,0,0,1,1,0, 0,0,0,0,1,1,0,0, 0,0,1,1,1,0,0,0],
];

/// One jittered sample of a digit class, normalised to [-1, 1] pixels.
pub fn sample_digit(class: usize, rng: &mut Prng) -> Vec<f64> {
    assert!(class < 10);
    let glyph = &GLYPHS[class];
    // global shift by up to 1 pixel in each axis
    let dx = rng.usize(3) as isize - 1;
    let dy = rng.usize(3) as isize - 1;
    let gain = rng.range(0.75, 1.0);
    let mut img = vec![0.0f64; DIM];
    for y in 0..SIDE as isize {
        for x in 0..SIDE as isize {
            let (sx, sy) = (x - dx, y - dy);
            if (0..SIDE as isize).contains(&sx) && (0..SIDE as isize).contains(&sy) {
                img[(y * SIDE as isize + x) as usize] =
                    glyph[(sy * SIDE as isize + sx) as usize] as f64 * gain;
            }
        }
    }
    // pixel noise + [-1,1] normalisation
    img.iter()
        .map(|&v| ((v + rng.normal(0.0, 0.12)).clamp(0.0, 1.0)) * 2.0 - 1.0)
        .collect()
}

/// A 10-class digits dataset: features [-1,1]^64, integer labels.
pub fn digits(n_train: usize, n_test: usize, seed: u64) -> (Dataset, Vec<usize>, Vec<usize>) {
    let mut rng = Prng::new(seed ^ 0xD161);
    let n = n_train + n_test;
    let mut xs = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for k in 0..n {
        let c = k % 10;
        xs.push(sample_digit(c, &mut rng));
        labels.push(c);
    }
    // shuffle while keeping xs/labels aligned
    let idx = rng.permutation(n);
    let xs2: Vec<Vec<f64>> = idx.iter().map(|&i| xs[i].clone()).collect();
    let l2: Vec<usize> = idx.iter().map(|&i| labels[i]).collect();
    xs = xs2;
    labels = l2;
    let ds = Dataset {
        name: "digits".into(),
        train_x: xs[..n_train].to_vec(),
        train_y: labels[..n_train].iter().map(|&c| c as f64).collect(),
        test_x: xs[n_train..].to_vec(),
        test_y: labels[n_train..].iter().map(|&c| c as f64).collect(),
    };
    (ds, labels[..n_train].to_vec(), labels[n_train..].to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let (ds, ltr, lte) = digits(200, 100, 1);
        assert_eq!(ds.d(), 64);
        assert_eq!(ds.n_train(), 200);
        assert_eq!(ds.n_test(), 100);
        assert_eq!(ltr.len(), 200);
        assert_eq!(lte.len(), 100);
        ds.validate().unwrap();
        assert!(ltr.iter().all(|&c| c < 10));
    }

    #[test]
    fn all_ten_classes_present() {
        let (_, ltr, _) = digits(200, 50, 2);
        for c in 0..10 {
            assert!(ltr.contains(&c), "class {c} missing");
        }
    }

    #[test]
    fn deterministic() {
        let (a, _, _) = digits(50, 20, 3);
        let (b, _, _) = digits(50, 20, 3);
        assert_eq!(a.train_x, b.train_x);
    }

    #[test]
    fn classes_are_visually_distinct() {
        // shift-aware nearest-prototype classifies noisy samples well
        // (samples carry +-1 pixel jitter, so match against all shifts)
        let mut rng = Prng::new(4);
        let shifted_protos: Vec<Vec<Vec<f64>>> = (0..10)
            .map(|c| {
                let mut variants = Vec::new();
                for dy in -1isize..=1 {
                    for dx in -1isize..=1 {
                        let mut img = vec![-1.0f64; DIM];
                        for y in 0..SIDE as isize {
                            for x in 0..SIDE as isize {
                                let (sx, sy) = (x - dx, y - dy);
                                if (0..SIDE as isize).contains(&sx)
                                    && (0..SIDE as isize).contains(&sy)
                                {
                                    img[(y * SIDE as isize + x) as usize] = GLYPHS[c]
                                        [(sy * SIDE as isize + sx) as usize]
                                        as f64
                                        * 2.0
                                        - 1.0;
                                }
                            }
                        }
                        variants.push(img);
                    }
                }
                variants
            })
            .collect();
        let dist = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).map(|(p, x)| (p - x) * (p - x)).sum()
        };
        let mut correct = 0;
        for _ in 0..200 {
            let c = rng.usize(10);
            let s = sample_digit(c, &mut rng);
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da = shifted_protos[a].iter().map(|p| dist(p, &s)).fold(f64::MAX, f64::min);
                    let db = shifted_protos[b].iter().map(|p| dist(p, &s)).fold(f64::MAX, f64::min);
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == c {
                correct += 1;
            }
        }
        assert!(correct > 150, "prototype accuracy {correct}/200");
    }
}
