//! Datasets: train/test containers, a CSV loader for real UCI files, and
//! deterministic synthetic generators ([`synth`]) standing in for the
//! paper's UCI downloads on this offline image (DESIGN.md §4).

pub mod digits;
pub mod synth;

/// A supervised dataset with a fixed train/test split. Features are
/// normalised to [-1, 1] (the chip's input mapping, Section III-D);
/// classification targets are +-1, regression targets are raw floats.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub train_x: Vec<Vec<f64>>,
    pub train_y: Vec<f64>,
    pub test_x: Vec<Vec<f64>>,
    pub test_y: Vec<f64>,
}

impl Dataset {
    pub fn d(&self) -> usize {
        self.train_x.first().map_or(0, |x| x.len())
    }

    pub fn n_train(&self) -> usize {
        self.train_x.len()
    }

    pub fn n_test(&self) -> usize {
        self.test_x.len()
    }

    /// Subsample the test set (for quick bench modes); deterministic.
    pub fn with_test_subsample(mut self, max: usize, seed: u64) -> Self {
        if self.test_x.len() <= max {
            return self;
        }
        let mut rng = crate::util::prng::Prng::new(seed);
        let idx = rng.permutation(self.test_x.len());
        let keep: Vec<usize> = idx.into_iter().take(max).collect();
        self.test_x = keep.iter().map(|&i| self.test_x[i].clone()).collect();
        self.test_y = keep.iter().map(|&i| self.test_y[i]).collect();
        self
    }

    /// Class balance of the training targets (fraction labelled +1);
    /// NaN-free even for regression sets.
    pub fn train_pos_fraction(&self) -> f64 {
        if self.train_y.is_empty() {
            return 0.0;
        }
        self.train_y.iter().filter(|&&y| y > 0.0).count() as f64 / self.train_y.len() as f64
    }

    /// Sanity checks used by the property tests.
    pub fn validate(&self) -> Result<(), String> {
        let d = self.d();
        if d == 0 {
            return Err("empty feature dimension".into());
        }
        for (k, x) in self.train_x.iter().chain(self.test_x.iter()).enumerate() {
            if x.len() != d {
                return Err(format!("ragged sample {k}"));
            }
            if x.iter().any(|v| !v.is_finite() || v.abs() > 1.0 + 1e-9) {
                return Err(format!("sample {k} outside [-1,1]"));
            }
        }
        if self.train_x.len() != self.train_y.len() || self.test_x.len() != self.test_y.len() {
            return Err("feature/target length mismatch".into());
        }
        Ok(())
    }
}

/// Parse a simple CSV (no quoting) with the label in the last column.
/// Features are min-max rescaled to [-1, 1] using *training* statistics.
/// Lets users drop real UCI files into `data/` to replace the synthetic
/// stand-ins.
pub fn load_csv(
    name: &str,
    train_csv: &str,
    test_csv: &str,
) -> Result<Dataset, String> {
    fn parse(text: &str) -> Result<(Vec<Vec<f64>>, Vec<f64>), String> {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let vals: Result<Vec<f64>, _> =
                line.split(',').map(|t| t.trim().parse::<f64>()).collect();
            let vals = vals.map_err(|e| format!("line {}: {e}", ln + 1))?;
            if vals.len() < 2 {
                return Err(format!("line {}: need features + label", ln + 1));
            }
            let (x, y) = vals.split_at(vals.len() - 1);
            xs.push(x.to_vec());
            ys.push(y[0]);
        }
        Ok((xs, ys))
    }
    let (mut train_x, train_y) = parse(train_csv)?;
    let (mut test_x, test_y) = parse(test_csv)?;
    let d = train_x.first().map_or(0, |x| x.len());
    // min-max from train split only
    let mut lo = vec![f64::MAX; d];
    let mut hi = vec![f64::MIN; d];
    for x in &train_x {
        for (j, &v) in x.iter().enumerate() {
            lo[j] = lo[j].min(v);
            hi[j] = hi[j].max(v);
        }
    }
    let rescale = |xs: &mut Vec<Vec<f64>>| {
        for x in xs {
            for (j, v) in x.iter_mut().enumerate() {
                let span = hi[j] - lo[j];
                *v = if span == 0.0 {
                    0.0
                } else {
                    ((*v - lo[j]) / span * 2.0 - 1.0).clamp(-1.0, 1.0)
                };
            }
        }
    };
    rescale(&mut train_x);
    rescale(&mut test_x);
    let ds = Dataset { name: name.to_string(), train_x, train_y, test_x, test_y };
    ds.validate()?;
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_and_rescale() {
        let train = "0,10,1\n5,20,-1\n10,30,1\n";
        let test = "5,25,-1\n";
        let ds = load_csv("toy", train, test).unwrap();
        assert_eq!(ds.d(), 2);
        assert_eq!(ds.n_train(), 3);
        assert_eq!(ds.n_test(), 1);
        assert_eq!(ds.train_x[0], vec![-1.0, -1.0]);
        assert_eq!(ds.train_x[2], vec![1.0, 1.0]);
        assert_eq!(ds.test_x[0], vec![0.0, 0.5]);
        assert_eq!(ds.train_y, vec![1.0, -1.0, 1.0]);
    }

    #[test]
    fn csv_rejects_garbage() {
        assert!(load_csv("bad", "1,notanumber,1\n", "").is_err());
        assert!(load_csv("bad", "1\n", "").is_err());
    }

    #[test]
    fn subsample_is_deterministic_and_bounded() {
        let ds = synth::brightdata(1).with_test_subsample(100, 7);
        assert_eq!(ds.n_test(), 100);
        let ds2 = synth::brightdata(1).with_test_subsample(100, 7);
        assert_eq!(ds.test_y, ds2.test_y);
    }
}
