//! Closed-loop serving load generator (`velm bench serve`, DESIGN.md
//! §16): boot a fleet in-process, hammer it from N closed-loop worker
//! threads (each waits for its answer before sending the next row),
//! then reduce the coordinator's own [`StatsSnapshot`] into a
//! versioned JSON benchmark report — the `BENCH_6.json` artifact CI
//! regenerates and schema-validates.
//!
//! With `--governor` the benchmark runs a second, governor-enabled leg
//! over an idle-heavy trace (burst, quiet window, burst) and reports it
//! next to the baseline under schema v2 — the `BENCH_7.json` artifact —
//! showing the governor descending to a cheap rung in the quiet window
//! and the exact fJ it saved (DESIGN.md §17). `gate_bench_json`
//! compares two reports and fails CI when throughput or end-to-end p99
//! regresses beyond a budget.
//!
//! With `--connections N` the benchmark instead adds the reactor
//! multiplexing leg (schema v3 — the `BENCH_8.json` artifact): the same
//! fleet is fronted by the connection reactor (DESIGN.md §20) and
//! driven from `N` real TCP connections, each keeping several
//! correlated requests in flight. The leg records the server-side
//! thread count next to the connection count, and the schema validator
//! re-asserts the reactor's core claim on every committed report: the
//! thread count is bounded by the pool size, independent of `N`.
//!
//! The report deliberately reuses the observability layer instead of
//! measuring on its own: the per-stage percentiles come from the same
//! histograms `STATS` serves, and the energy figures from the same
//! ledger the workers price conversions into — so the benchmark also
//! exercises the telemetry path it reports through.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::client::Client;
use crate::config::{ChipConfig, SystemConfig, Transfer};
use crate::coordinator::{reactor, Coordinator};
use crate::datasets::synth;
use crate::governor::GovernorConfig;
use crate::protocol::{Request, Response, Segment, StageStats, StatsSnapshot};
use crate::sync::Ordering;
use crate::util::json::Value;

/// Schema tag stamped into every report; bump with the field set.
pub const BENCH_SCHEMA: &str = "velm-bench-serve/1";

/// Schema tag for reports carrying the governor comparison leg.
pub const BENCH_SCHEMA_V2: &str = "velm-bench-serve/2";

/// Schema tag for reports carrying the reactor multiplexing leg.
pub const BENCH_SCHEMA_V3: &str = "velm-bench-serve/3";

/// Correlated requests each bench connection keeps in flight on the
/// reactor leg — the multiplexing depth `BENCH_8.json` records.
const REACTOR_DEPTH: usize = 4;

/// One benchmark run's shape.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Synthetic dataset the fleet trains and serves on.
    pub dataset: String,
    pub seed: u64,
    /// Total rows to serve across all closed-loop workers.
    pub requests: usize,
    /// Closed-loop client threads.
    pub concurrency: usize,
    /// Dies in the fleet.
    pub chips: usize,
    /// Cap on the training set (0 = full) — smoke runs train fast.
    pub max_train: usize,
    /// Also run the governor-enabled comparison leg over an idle-heavy
    /// trace and emit a schema-v2 report (DESIGN.md §17).
    pub governor: bool,
    /// `Some(rate)` switches the baseline leg from closed-loop to
    /// open-loop Poisson arrivals at `rate` req/s: send instants come
    /// from a seeded exponential inter-arrival schedule, so queue
    /// pressure reflects the arrival process instead of the fleet's own
    /// service rate. The governed comparison leg always keeps its
    /// hand-driven idle-heavy trace — its fJ accounting is pinned.
    pub arrival: Option<f64>,
    /// `Some(n)` adds the reactor multiplexing leg (schema v3,
    /// `BENCH_8.json`): `n` real TCP connections through the
    /// connection reactor, each pipelining [`REACTOR_DEPTH`]
    /// correlated requests. Mutually exclusive with `governor`.
    pub connections: Option<usize>,
}

impl BenchConfig {
    /// The CI smoke shape: small enough for seconds, large enough to
    /// populate every stage histogram.
    pub fn smoke() -> BenchConfig {
        BenchConfig {
            dataset: "brightdata".into(),
            seed: 1,
            requests: 400,
            concurrency: 4,
            chips: 2,
            max_train: 200,
            governor: false,
            arrival: None,
            connections: None,
        }
    }

    /// The default (non-smoke) shape.
    pub fn full() -> BenchConfig {
        BenchConfig { requests: 4000, max_train: 0, ..BenchConfig::smoke() }
    }
}

/// The governor-enabled comparison leg of a v2 report: same request
/// count as the baseline, served as an idle-heavy trace so the governor
/// gets a quiet window to descend in (DESIGN.md §17).
#[derive(Clone, Debug)]
pub struct GovernorLeg {
    pub responses: u64,
    pub elapsed_us: u64,
    pub throughput_rps: f64,
    /// End-to-end p99 over the whole leg — burst rows included, so a
    /// governor that holds a cheap rung into the burst shows up here.
    pub p99_us: u64,
    pub energy_fj: u64,
    /// Exact fJ the cheap rung saved vs boot pricing (integer ledger).
    pub fj_saved: u64,
    pub ticks: u64,
    pub raises: u64,
    pub lowers: u64,
    /// Final per-die operating points (counter bits).
    pub points: Vec<u32>,
}

/// The reactor multiplexing leg of a v3 report: `connections` real TCP
/// clients through the connection reactor (DESIGN.md §20), each with
/// `in_flight_depth` correlated requests pipelined. The headline pair
/// is `thread_count` vs `connections`: the reactor serves every
/// connection from `pool_workers + 2` threads.
#[derive(Clone, Debug)]
pub struct ReactorLeg {
    pub connections: u64,
    /// Reactor worker-pool size the fleet was configured with.
    pub pool_workers: u64,
    /// Total server-side threads the reactor spawned — bounded by
    /// `pool_workers + 2` (workers + acceptor + poll loop) no matter
    /// how many connections dialled in.
    pub thread_count: u64,
    /// Correlated requests each connection kept in flight.
    pub in_flight_depth: u64,
    /// Peak simultaneous in-flight requests the poll loop observed
    /// across all connections.
    pub peak_in_flight: u64,
    /// Peak simultaneous open connections.
    pub peak_conns: u64,
    pub responses: u64,
    pub elapsed_us: u64,
    pub throughput_rps: f64,
}

/// What one run produced: wall-clock plus the coordinator's final
/// snapshot (stage histograms, energy ledger, counters), and the
/// governor comparison leg when the run asked for one.
#[derive(Clone, Debug)]
pub struct BenchReport {
    pub dataset: String,
    pub requests: u64,
    pub elapsed_us: u64,
    pub snapshot: StatsSnapshot,
    pub governor: Option<GovernorLeg>,
    pub reactor: Option<ReactorLeg>,
}

impl BenchReport {
    /// Served rows per wall-clock second.
    pub fn throughput_rps(&self) -> f64 {
        if self.elapsed_us == 0 {
            0.0
        } else {
            self.snapshot.responses as f64 / (self.elapsed_us as f64 * 1e-6)
        }
    }

    /// Render the versioned JSON report — [`BENCH_SCHEMA`], or
    /// [`BENCH_SCHEMA_V2`] when the governor leg rode along.
    pub fn to_json(&self) -> String {
        let u = |n: u64| Value::Num(n as f64);
        let stage = |s: &StageStats| {
            Value::Obj(vec![
                ("count".into(), u(s.count)),
                ("p50_us".into(), u(s.p50_us)),
                ("p90_us".into(), u(s.p90_us)),
                ("p99_us".into(), u(s.p99_us)),
                ("mean_us".into(), Value::Num(s.mean_us())),
            ])
        };
        let schema = if self.reactor.is_some() {
            BENCH_SCHEMA_V3
        } else if self.governor.is_some() {
            BENCH_SCHEMA_V2
        } else {
            BENCH_SCHEMA
        };
        let s = &self.snapshot;
        let mut fields = vec![
            ("schema".into(), Value::Str(schema.into())),
            ("dataset".into(), Value::Str(self.dataset.clone())),
            ("requests".into(), u(self.requests)),
            ("responses".into(), u(s.responses)),
            ("elapsed_us".into(), u(self.elapsed_us)),
            ("throughput_rps".into(), Value::Num(self.throughput_rps())),
            ("conversions".into(), u(s.conversions)),
            ("energy_fj".into(), u(s.energy_fj)),
            ("macs".into(), u(s.macs)),
            ("pj_per_mac".into(), Value::Num(s.pj_per_mac())),
            (
                "stages".into(),
                Value::Obj(vec![
                    ("total".into(), stage(&s.latency)),
                    ("queue".into(), stage(&s.queue)),
                    ("batch_wait".into(), stage(&s.batch_wait)),
                    ("compute".into(), stage(&s.compute)),
                ]),
            ),
            // per-die occupancy summary (DESIGN.md §19): where each die's
            // wall clock went over the run, as fractions that sum to 1.0
            (
                "occupancy".into(),
                Value::Arr(
                    s.occupancy
                        .iter()
                        .map(|o| {
                            let fr = o.fractions();
                            Value::Obj(vec![
                                ("die".into(), u(o.die as u64)),
                                ("total_us".into(), u(o.total_us())),
                                (
                                    "fractions".into(),
                                    Value::Obj(
                                        Segment::ALL
                                            .iter()
                                            .map(|seg| {
                                                (
                                                    seg.name().to_string(),
                                                    Value::Num(fr[seg.code() as usize]),
                                                )
                                            })
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        if let Some(g) = &self.governor {
            fields.push((
                "governor".into(),
                Value::Obj(vec![
                    ("responses".into(), u(g.responses)),
                    ("elapsed_us".into(), u(g.elapsed_us)),
                    ("throughput_rps".into(), Value::Num(g.throughput_rps)),
                    ("p99_us".into(), u(g.p99_us)),
                    ("energy_fj".into(), u(g.energy_fj)),
                    ("fj_saved".into(), u(g.fj_saved)),
                    ("ticks".into(), u(g.ticks)),
                    ("raises".into(), u(g.raises)),
                    ("lowers".into(), u(g.lowers)),
                    (
                        "points".into(),
                        Value::Arr(g.points.iter().map(|&b| u(b as u64)).collect()),
                    ),
                ]),
            ));
        }
        if let Some(r) = &self.reactor {
            fields.push((
                "reactor".into(),
                Value::Obj(vec![
                    ("connections".into(), u(r.connections)),
                    ("pool_workers".into(), u(r.pool_workers)),
                    ("thread_count".into(), u(r.thread_count)),
                    ("in_flight_depth".into(), u(r.in_flight_depth)),
                    ("peak_in_flight".into(), u(r.peak_in_flight)),
                    ("peak_conns".into(), u(r.peak_conns)),
                    ("responses".into(), u(r.responses)),
                    ("elapsed_us".into(), u(r.elapsed_us)),
                    ("throughput_rps".into(), Value::Num(r.throughput_rps)),
                ]),
            ));
        }
        let mut out = String::new();
        Value::Obj(fields).write(&mut out);
        out
    }
}

/// Check a bench report document against its schema: the tag, every
/// counter, the derived rates and all four stage blocks must be present
/// and self-consistent. Schema v2 ([`BENCH_SCHEMA_V2`]) additionally
/// requires the governor comparison leg, and requires it to actually
/// demonstrate the saving: positive `fj_saved` and less energy than the
/// baseline leg for the same request count. Schema v3
/// ([`BENCH_SCHEMA_V3`]) requires the reactor multiplexing leg instead,
/// and asserts the reactor's core claim: the server thread count is
/// bounded by the pool size (`pool_workers + 2`), independent of the
/// connection count. CI runs this over the committed
/// `BENCH_6.json`/`BENCH_7.json`/`BENCH_8.json` after regenerating them.
pub fn validate_bench_json(text: &str) -> Result<(), String> {
    let v = Value::parse(text)?;
    let schema = v.get("schema").and_then(Value::as_str).ok_or("missing 'schema'")?;
    if schema != BENCH_SCHEMA && schema != BENCH_SCHEMA_V2 && schema != BENCH_SCHEMA_V3 {
        return Err(format!(
            "schema '{schema}' != '{BENCH_SCHEMA}', '{BENCH_SCHEMA_V2}' or '{BENCH_SCHEMA_V3}'"
        ));
    }
    v.get("dataset").and_then(Value::as_str).ok_or("missing 'dataset'")?;
    let u = |k: &str| v.get(k).and_then(Value::as_u64).ok_or(format!("missing '{k}'"));
    let f = |k: &str| {
        v.get(k)
            .and_then(Value::as_f64)
            .filter(|x| x.is_finite() && *x >= 0.0)
            .ok_or(format!("missing or non-finite '{k}'"))
    };
    let requests = u("requests")?;
    let responses = u("responses")?;
    if requests == 0 {
        return Err("a bench report must serve at least one request".into());
    }
    if responses > requests {
        return Err(format!("responses {responses} > requests {requests}"));
    }
    if u("elapsed_us")? == 0 {
        return Err("elapsed_us must be positive".into());
    }
    f("throughput_rps")?;
    f("pj_per_mac")?;
    u("conversions")?;
    u("energy_fj")?;
    u("macs")?;
    let stages = v.get("stages").ok_or("missing 'stages'")?;
    for key in ["total", "queue", "batch_wait", "compute"] {
        let s = stages.get(key).ok_or(format!("missing stage '{key}'"))?;
        let su = |k: &str| {
            s.get(k)
                .and_then(Value::as_u64)
                .ok_or(format!("stage '{key}' missing '{k}'"))
        };
        let count = su("count")?;
        let (p50, p99) = (su("p50_us")?, su("p99_us")?);
        su("p90_us")?;
        if count > 0 && p50 > p99 {
            return Err(format!("stage '{key}': p50 {p50} > p99 {p99}"));
        }
    }
    match (schema == BENCH_SCHEMA_V3, v.get("reactor")) {
        (false, None) => {}
        (false, Some(_)) => return Err("a reactor block needs schema v3".into()),
        (true, None) => return Err("schema v3 requires the 'reactor' block".into()),
        (true, Some(r)) => {
            let ru = |k: &str| {
                r.get(k)
                    .and_then(Value::as_u64)
                    .ok_or(format!("reactor block missing '{k}'"))
            };
            let connections = ru("connections")?;
            if connections == 0 {
                return Err("reactor leg drove no connections".into());
            }
            if ru("in_flight_depth")? == 0 {
                return Err("reactor leg must keep at least one request in flight".into());
            }
            if ru("responses")? == 0 {
                return Err("reactor leg served no rows".into());
            }
            if ru("elapsed_us")? == 0 {
                return Err("reactor elapsed_us must be positive".into());
            }
            r.get("throughput_rps")
                .and_then(Value::as_f64)
                .filter(|x| x.is_finite() && *x >= 0.0)
                .ok_or("reactor block missing 'throughput_rps'")?;
            ru("peak_in_flight")?;
            ru("peak_conns")?;
            // the reactor's core claim, re-asserted on every committed
            // report: server threads are a function of the pool size,
            // never of how many connections dialled in
            let (pool, threads) = (ru("pool_workers")?, ru("thread_count")?);
            if pool == 0 {
                return Err("reactor pool_workers must be positive".into());
            }
            if threads > pool + 2 {
                return Err(format!(
                    "reactor thread_count {threads} exceeds pool bound {} \
                     (workers + acceptor + poll loop) at {connections} connections",
                    pool + 2
                ));
            }
        }
    }
    match (schema == BENCH_SCHEMA_V2, v.get("governor")) {
        (false, None) => Ok(()),
        (false, Some(_)) => Err("a governor block needs schema v2".into()),
        (true, None) => Err("schema v2 requires the 'governor' block".into()),
        (true, Some(g)) => {
            let gu = |k: &str| {
                g.get(k)
                    .and_then(Value::as_u64)
                    .ok_or(format!("governor block missing '{k}'"))
            };
            if gu("responses")? == 0 {
                return Err("governor leg served no rows".into());
            }
            if gu("elapsed_us")? == 0 {
                return Err("governor elapsed_us must be positive".into());
            }
            g.get("throughput_rps")
                .and_then(Value::as_f64)
                .filter(|x| x.is_finite() && *x >= 0.0)
                .ok_or("governor block missing 'throughput_rps'")?;
            gu("p99_us")?;
            gu("ticks")?;
            gu("raises")?;
            gu("lowers")?;
            let points = g
                .get("points")
                .and_then(Value::as_arr)
                .ok_or("governor block missing 'points'")?;
            if points.iter().any(|p| p.as_u64().is_none()) {
                return Err("governor points must be unsigned bit counts".into());
            }
            // the leg must actually demonstrate the saving: the quiet
            // window descends to a cheaper rung, so the same trace
            // costs strictly less fleet energy than the baseline
            if gu("fj_saved")? == 0 {
                return Err("governor leg saved no energy (fj_saved == 0)".into());
            }
            if gu("energy_fj")? >= u("energy_fj")? {
                return Err("governor leg must cost less energy than the baseline".into());
            }
            Ok(())
        }
    }
}

/// Regression gate over two bench reports (`velm bench gate`): compare
/// the current report against a previous one and fail when throughput
/// drops, or end-to-end p99 rises, by more than `max_regress`
/// (a fraction: 0.10 allows 10%). Any schema version is accepted —
/// the gated figures live in the baseline body of all three. Returns a
/// printable comparison on success.
pub fn gate_bench_json(
    current: &str,
    previous: &str,
    max_regress: f64,
) -> Result<String, String> {
    let read = |text: &str, which: &str| -> Result<(f64, u64), String> {
        let v = Value::parse(text).map_err(|e| format!("{which}: {e}"))?;
        let schema = v
            .get("schema")
            .and_then(Value::as_str)
            .ok_or(format!("{which}: missing 'schema'"))?;
        if schema != BENCH_SCHEMA && schema != BENCH_SCHEMA_V2 && schema != BENCH_SCHEMA_V3 {
            return Err(format!("{which}: unknown schema '{schema}'"));
        }
        let rps = v
            .get("throughput_rps")
            .and_then(Value::as_f64)
            .filter(|x| x.is_finite() && *x >= 0.0)
            .ok_or(format!("{which}: missing 'throughput_rps'"))?;
        let p99 = v
            .get("stages")
            .and_then(|s| s.get("total"))
            .and_then(|t| t.get("p99_us"))
            .and_then(Value::as_u64)
            .ok_or(format!("{which}: missing stages.total.p99_us"))?;
        Ok((rps, p99))
    };
    let (cur_rps, cur_p99) = read(current, "current")?;
    let (prev_rps, prev_p99) = read(previous, "previous")?;
    let allow = max_regress.max(0.0);
    let verdict = format!(
        "throughput {cur_rps:.1} rps vs {prev_rps:.1} rps, \
         p99 {cur_p99} us vs {prev_p99} us (budget {:.0}%)",
        allow * 100.0
    );
    if cur_rps < prev_rps * (1.0 - allow) {
        return Err(format!("throughput regressed beyond the budget: {verdict}"));
    }
    if prev_p99 > 0 && cur_p99 as f64 > prev_p99 as f64 * (1.0 + allow) {
        return Err(format!("p99 regressed beyond the budget: {verdict}"));
    }
    Ok(verdict)
}

/// Boot a fleet per `cfg`, drive it closed-loop, return the report.
/// With `cfg.governor` a second, governor-enabled fleet serves the same
/// request count as an idle-heavy trace and lands in the report's
/// comparison leg.
pub fn run(cfg: &BenchConfig) -> Result<BenchReport> {
    anyhow::ensure!(
        !(cfg.governor && cfg.connections.is_some()),
        "--governor and --connections are separate comparison legs; run one at a time"
    );
    let (snapshot, elapsed_us, requests) = drive(cfg, false)?;
    let governor = if cfg.governor {
        let (gs, ge, _) = drive(cfg, true)?;
        let throughput_rps =
            if ge == 0 { 0.0 } else { gs.responses as f64 / (ge as f64 * 1e-6) };
        Some(GovernorLeg {
            responses: gs.responses,
            elapsed_us: ge,
            throughput_rps,
            p99_us: gs.latency.p99_us,
            energy_fj: gs.energy_fj,
            fj_saved: gs.governor.fj_saved,
            ticks: gs.governor.ticks,
            raises: gs.governor.raises,
            lowers: gs.governor.lowers,
            points: gs.governor.points.clone(),
        })
    } else {
        None
    };
    let reactor = match cfg.connections {
        Some(n) => Some(drive_reactor(cfg, n.max(1))?),
        None => None,
    };
    Ok(BenchReport {
        dataset: cfg.dataset.clone(),
        requests,
        elapsed_us,
        snapshot,
        governor,
        reactor,
    })
}

/// One benchmark leg: boot a fleet (governed or not), drive it
/// closed-loop, return (final snapshot, elapsed us, requests sent).
///
/// The governed leg serves the idle-heavy trace: half the rows as a
/// burst at the boot point, a quiet window in which a hand-driven
/// governor tick descends the ladder, the other half on the cheap
/// rung, then a final tick that restores the boot point. Ticks are
/// manual (the thread is parked on a huge period) so the descent — and
/// with it the report's `fj_saved` — is deterministic.
fn drive(cfg: &BenchConfig, governed: bool) -> Result<(StatsSnapshot, u64, u64)> {
    let mut ds = synth::by_name(&cfg.dataset, cfg.seed)
        .with_context(|| format!("unknown dataset {}", cfg.dataset))?;
    if cfg.max_train > 0 && ds.train_x.len() > cfg.max_train {
        ds.train_x.truncate(cfg.max_train);
        ds.train_y.truncate(cfg.max_train);
    }
    let mut sys = SystemConfig {
        n_chips: cfg.chips.max(1),
        max_wait: Duration::from_millis(1),
        seed: cfg.seed,
        artifact_dir: "/nonexistent".into(),
        ..SystemConfig::default()
    };
    if governed {
        sys.governor = GovernorConfig {
            enabled: true,
            tick: Duration::from_secs(3600), // ticks are driven by hand
            cooldown_ticks: 0,
            window_ticks: 1_000,
            max_moves_per_window: 1_000,
            hot_queue_us: 0, // any traffic at all reads as hot
            bits: vec![6],   // one low-energy rung under the b=10 boot
            ..GovernorConfig::default()
        };
    }
    let chip = ChipConfig::default()
        .with_dims(ds.d(), 24)
        .with_b(10)
        .with_mode(Transfer::Quadratic);
    let coord = Arc::new(Coordinator::start(&sys, &chip, &ds.train_x, &ds.train_y, 0.1, 10)?);
    let workers = cfg.concurrency.max(1);
    let per = (cfg.requests / workers).max(1);
    let xs = &ds.train_x;
    let t0 = Instant::now();
    // one closed-loop phase: every worker serves its `rows` range and
    // waits for each answer before sending the next row
    let phase = |rows: std::ops::Range<usize>| -> Result<()> {
        std::thread::scope(|scope| -> Result<()> {
            let mut joins = Vec::new();
            for w in 0..workers {
                let coord = Arc::clone(&coord);
                let rows = rows.clone();
                joins.push(scope.spawn(move || -> Result<()> {
                    for i in rows {
                        coord.classify(xs[(w * per + i) % xs.len()].clone())?;
                    }
                    Ok(())
                }));
            }
            for j in joins {
                j.join().map_err(|_| anyhow::anyhow!("bench worker panicked"))??;
            }
            Ok(())
        })
    };
    if governed {
        let split = per / 2;
        phase(0..split)?;
        coord.governor_tick(); // absorbs the burst delta (hot, at boot)
        coord.governor_tick(); // quiet window: descend one rung
        // the tick blocks on each worker's retune ack, so the whole
        // second burst is already priced on the cheap rung
        phase(split..per)?;
        coord.governor_tick(); // traffic again: restore the boot point
    } else if let Some(rate) = cfg.arrival {
        open_loop(&coord, xs, workers, per, rate, cfg.seed)?;
    } else {
        phase(0..per)?;
    }
    let elapsed_us = (t0.elapsed().as_micros() as u64).max(1);
    let snapshot = coord.snapshot();
    if let Ok(coord) = Arc::try_unwrap(coord) {
        coord.shutdown();
    }
    Ok((snapshot, elapsed_us, (per * workers) as u64))
}

/// Open-loop Poisson drive (`--arrival poisson:RATE`): a seeded LCG
/// draws exponential inter-arrival gaps for `per * workers` rows, the
/// resulting absolute send instants are dealt round-robin to the
/// client threads, and each thread sleeps until an instant is due
/// before submitting its row. Arrivals keep coming while earlier rows
/// are still queued — the defining open-loop property — though each
/// thread still waits out its own reply, so in-flight rows are bounded
/// at `workers`. The schedule is a pure function of the seed: two runs
/// at the same rate submit at the same offsets.
fn open_loop(
    coord: &Arc<Coordinator>,
    xs: &[Vec<f64>],
    workers: usize,
    per: usize,
    rate: f64,
    seed: u64,
) -> Result<()> {
    let total = per * workers;
    let mut lcg = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
    let mut at = 0.0f64;
    let mut schedule = Vec::with_capacity(total);
    for _ in 0..total {
        lcg = lcg.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        // top 53 bits -> uniform in (0, 1], inverted to an Exp(rate) gap
        let u = ((lcg >> 11) as f64 + 1.0) / (1u64 << 53) as f64;
        at += -u.ln() / rate;
        schedule.push(Duration::from_secs_f64(at));
    }
    let t0 = Instant::now();
    std::thread::scope(|scope| -> Result<()> {
        let mut joins = Vec::new();
        for w in 0..workers {
            let coord = Arc::clone(coord);
            let schedule = &schedule;
            joins.push(scope.spawn(move || -> Result<()> {
                for i in (w..total).step_by(workers) {
                    let due = t0 + schedule[i];
                    let now = Instant::now();
                    if due > now {
                        std::thread::sleep(due - now);
                    }
                    coord.classify(xs[i % xs.len()].clone())?;
                }
                Ok(())
            }));
        }
        for j in joins {
            j.join().map_err(|_| anyhow::anyhow!("bench worker panicked"))??;
        }
        Ok(())
    })
}

/// The reactor multiplexing leg (`--connections N`, DESIGN.md §20):
/// boot the same fleet shape, put the connection reactor in front of
/// it, and drive it from `conns` real TCP connections. Each connection
/// keeps [`REACTOR_DEPTH`] correlated requests in flight — replies are
/// reaped in completion order while later rows are already on the wire,
/// so queue pressure comes from the pipeline, not from per-row
/// round-trip latency. The leg's point is the thread accounting: the
/// server side stays at `reactor_workers + 2` threads regardless of
/// `conns`, which the schema validator re-asserts on every committed
/// `BENCH_8.json`.
fn drive_reactor(cfg: &BenchConfig, conns: usize) -> Result<ReactorLeg> {
    let mut ds = synth::by_name(&cfg.dataset, cfg.seed)
        .with_context(|| format!("unknown dataset {}", cfg.dataset))?;
    if cfg.max_train > 0 && ds.train_x.len() > cfg.max_train {
        ds.train_x.truncate(cfg.max_train);
        ds.train_y.truncate(cfg.max_train);
    }
    let sys = SystemConfig {
        n_chips: cfg.chips.max(1),
        max_wait: Duration::from_millis(1),
        seed: cfg.seed,
        artifact_dir: "/nonexistent".into(),
        ..SystemConfig::default()
    };
    let chip = ChipConfig::default()
        .with_dims(ds.d(), 24)
        .with_b(10)
        .with_mode(Transfer::Quadratic);
    let coord = Arc::new(Coordinator::start(&sys, &chip, &ds.train_x, &ds.train_y, 0.1, 10)?);
    let rcfg = reactor::ReactorConfig {
        workers: coord.reactor_workers,
        read_timeout: coord.read_timeout,
        max_conns: Some(conns),
    };
    let handle = reactor::spawn(Arc::clone(&coord), "127.0.0.1:0", rcfg)?;
    let (addr, gauges) = (handle.addr, Arc::clone(&handle.gauges));
    let pool_workers = coord.reactor_workers as u64;
    let thread_count = handle.thread_count() as u64;
    let per = (cfg.requests / conns).max(REACTOR_DEPTH);
    let xs = &ds.train_x;
    let t0 = Instant::now();
    std::thread::scope(|scope| -> Result<()> {
        let mut joins = Vec::new();
        for c in 0..conns {
            joins.push(scope.spawn(move || -> Result<()> {
                let mut client = Client::connect(addr)?;
                let (mut sent, mut got, mut in_flight) = (0usize, 0usize, 0usize);
                while got < per {
                    // top the pipeline back up to full depth, then
                    // reap exactly one reply (completion order)
                    while sent < per && in_flight < REACTOR_DEPTH {
                        client.send_pipelined(&Request::Predict {
                            tenant: None,
                            features: xs[(c * per + sent) % xs.len()].clone(),
                        })?;
                        sent += 1;
                        in_flight += 1;
                    }
                    match client.recv_pipelined()? {
                        (_, Response::Predict(_)) => got += 1,
                        (_, other) => anyhow::bail!("unexpected reactor reply: {other:?}"),
                    }
                    in_flight -= 1;
                }
                Ok(())
            }));
        }
        for j in joins {
            j.join().map_err(|_| anyhow::anyhow!("reactor bench client panicked"))??;
        }
        Ok(())
    })?;
    let elapsed_us = (t0.elapsed().as_micros() as u64).max(1);
    handle.join(); // every client hung up; the reactor drains and exits
    // relaxed-ok: the poll loop exited at the join above — these gauges
    // are quiesced counters now, not racing telemetry
    let peak_in_flight = gauges.peak_in_flight.load(Ordering::Relaxed) as u64;
    let peak_conns = gauges.peak_conns.load(Ordering::Relaxed) as u64;
    if let Ok(coord) = Arc::try_unwrap(coord) {
        coord.shutdown();
    }
    let responses = (per * conns) as u64;
    Ok(ReactorLeg {
        connections: conns as u64,
        pool_workers,
        thread_count,
        in_flight_depth: REACTOR_DEPTH as u64,
        peak_in_flight,
        peak_conns,
        responses,
        elapsed_us,
        throughput_rps: responses as f64 / (elapsed_us as f64 * 1e-6),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_run_produces_a_valid_self_consistent_report() {
        let cfg = BenchConfig {
            requests: 60,
            concurrency: 3,
            chips: 2,
            max_train: 120,
            ..BenchConfig::smoke()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.requests, 60);
        let s = &report.snapshot;
        assert_eq!(s.responses, 60, "closed-loop rows must all answer");
        assert_eq!(s.queue.count, 60);
        assert_eq!(s.batch_wait.count, 60);
        assert_eq!(s.compute.count, 60);
        assert!(s.energy_fj > 0, "served conversions must be priced");
        assert!(s.macs > 0);
        assert!(report.throughput_rps() > 0.0);
        validate_bench_json(&report.to_json()).unwrap();
    }

    #[test]
    fn open_loop_poisson_leg_answers_every_row_and_reports_occupancy() {
        let cfg = BenchConfig {
            requests: 40,
            concurrency: 2,
            chips: 2,
            max_train: 120,
            arrival: Some(2000.0), // ~20 ms of scheduled arrivals
            ..BenchConfig::smoke()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.snapshot.responses, 40, "open-loop rows must all answer");
        let json = report.to_json();
        assert!(json.contains("\"occupancy\":["), "{json}");
        validate_bench_json(&json).unwrap();
        // the fleet profiled real wall clock, and wherever a die
        // stamped at all its fractions tile that clock exactly
        assert!(report.snapshot.occupancy.iter().any(|o| o.total_us() > 0));
        for o in &report.snapshot.occupancy {
            let sum: f64 = o.fractions().iter().sum();
            assert!(
                sum == 0.0 || (sum - 1.0).abs() < 1e-9,
                "die {}: fractions sum {sum}",
                o.die
            );
        }
    }

    #[test]
    fn validator_rejects_broken_reports() {
        assert!(validate_bench_json("not json").is_err());
        assert!(validate_bench_json("{}").is_err());
        let err = validate_bench_json(r#"{"schema":"wrong/9"}"#).unwrap_err();
        assert!(err.contains("schema"), "{err}");
        // a report whose counters contradict each other is refused
        let cfg = BenchConfig {
            requests: 20,
            concurrency: 2,
            chips: 1,
            max_train: 120,
            ..BenchConfig::smoke()
        };
        let mut report = run(&cfg).unwrap();
        report.snapshot.responses = report.requests + 5;
        let err = validate_bench_json(&report.to_json()).unwrap_err();
        assert!(err.contains("responses"), "{err}");
    }

    #[test]
    fn committed_bench_artifact_passes_the_schema() {
        // the repo-root BENCH_6.json is regenerated by CI via
        // `velm bench serve --smoke`; whatever is committed must parse
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_6.json");
        let text = std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("reading {path}: {e}"));
        validate_bench_json(&text).unwrap_or_else(|e| panic!("{path}: {e}"));
    }

    #[test]
    fn committed_governor_bench_artifact_passes_the_schema() {
        // BENCH_7.json (the governor comparison, schema v2) is
        // regenerated by CI via `velm bench serve --smoke --governor`
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_7.json");
        let text = std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("reading {path}: {e}"));
        validate_bench_json(&text).unwrap_or_else(|e| panic!("{path}: {e}"));
    }

    #[test]
    fn committed_reactor_bench_artifact_passes_the_schema() {
        // BENCH_8.json (the reactor multiplexing leg, schema v3) is
        // regenerated by CI via `velm bench serve --smoke
        // --connections 16`; whatever is committed must parse and must
        // uphold the thread bound the validator asserts
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_8.json");
        let text = std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("reading {path}: {e}"));
        validate_bench_json(&text).unwrap_or_else(|e| panic!("{path}: {e}"));
    }

    #[test]
    fn reactor_leg_multiplexes_connections_over_a_bounded_pool() {
        let cfg = BenchConfig {
            requests: 48,
            concurrency: 2,
            chips: 1,
            max_train: 120,
            connections: Some(6),
            ..BenchConfig::smoke()
        };
        let report = run(&cfg).unwrap();
        let r = report.reactor.as_ref().expect("reactor leg");
        assert_eq!(r.connections, 6);
        assert_eq!(r.responses, 48, "every pipelined row must answer: {r:?}");
        assert_eq!(r.in_flight_depth, REACTOR_DEPTH as u64);
        // the reactor's whole point: 6 connections, workers + 2 threads
        assert_eq!(
            r.thread_count,
            r.pool_workers + 2,
            "reactor threads = workers + acceptor + poll loop: {r:?}"
        );
        assert!(r.peak_conns >= 2, "connections must overlap: {r:?}");
        assert!(r.peak_in_flight >= 2, "requests must pipeline: {r:?}");
        let json = report.to_json();
        assert!(json.contains(BENCH_SCHEMA_V3), "{json}");
        validate_bench_json(&json).unwrap();
    }

    #[test]
    fn validator_polices_the_reactor_block() {
        // v3 without the block, and the block outside v3, both fail
        let base = r#""dataset":"d","requests":1,"responses":1,"elapsed_us":1,
            "throughput_rps":1.0,"conversions":1,"energy_fj":10,"macs":1,
            "pj_per_mac":0.1,
            "stages":{"total":{"count":1,"p50_us":1,"p90_us":1,"p99_us":1},
                      "queue":{"count":1,"p50_us":1,"p90_us":1,"p99_us":1},
                      "batch_wait":{"count":1,"p50_us":1,"p90_us":1,"p99_us":1},
                      "compute":{"count":1,"p50_us":1,"p90_us":1,"p99_us":1}}"#;
        let err =
            validate_bench_json(&format!(r#"{{"schema":"velm-bench-serve/3",{base}}}"#))
                .unwrap_err();
        assert!(err.contains("reactor"), "{err}");
        let err = validate_bench_json(&format!(
            r#"{{"schema":"velm-bench-serve/1",{base},"reactor":{{}}}}"#
        ))
        .unwrap_err();
        assert!(err.contains("schema v3"), "{err}");
        // a report claiming more threads than the pool bound is refused
        // no matter the connection count — that's the claim CI re-checks
        let cfg = BenchConfig {
            requests: 16,
            concurrency: 2,
            chips: 1,
            max_train: 120,
            connections: Some(2),
            ..BenchConfig::smoke()
        };
        let mut report = run(&cfg).unwrap();
        report.reactor.as_mut().unwrap().thread_count = 999;
        let err = validate_bench_json(&report.to_json()).unwrap_err();
        assert!(err.contains("thread_count"), "{err}");
    }

    #[test]
    fn governor_leg_saves_energy_and_reports_under_schema_v2() {
        let cfg = BenchConfig {
            requests: 60,
            concurrency: 3,
            chips: 2,
            max_train: 120,
            governor: true,
            ..BenchConfig::smoke()
        };
        let report = run(&cfg).unwrap();
        let g = report.governor.as_ref().expect("comparison leg");
        assert_eq!(g.responses, 60, "the governed leg serves the same trace");
        assert!(g.lowers >= 1, "the quiet window must descend: {g:?}");
        assert!(g.raises >= 1, "the second burst must restore boot: {g:?}");
        assert!(g.fj_saved > 0, "the cheap rung must save energy: {g:?}");
        assert!(
            g.energy_fj < report.snapshot.energy_fj,
            "governed {} fJ vs baseline {} fJ",
            g.energy_fj,
            report.snapshot.energy_fj
        );
        // the ledger is exact: saved + spent == boot-priced spend, so
        // the two legs' energies differ by exactly the saving
        assert_eq!(g.energy_fj + g.fj_saved, report.snapshot.energy_fj);
        assert_eq!(g.points, vec![10, 10], "final tick restores both dies");
        let json = report.to_json();
        assert!(json.contains(BENCH_SCHEMA_V2), "{json}");
        validate_bench_json(&json).unwrap();
    }

    #[test]
    fn validator_polices_the_governor_block() {
        // v1 must not carry a governor block; v2 must carry a valid one
        let err = validate_bench_json(
            r#"{"schema":"velm-bench-serve/2","dataset":"d","requests":1,
                "responses":1,"elapsed_us":1,"throughput_rps":1.0,
                "conversions":1,"energy_fj":10,"macs":1,"pj_per_mac":0.1,
                "stages":{"total":{"count":1,"p50_us":1,"p90_us":1,"p99_us":1},
                          "queue":{"count":1,"p50_us":1,"p90_us":1,"p99_us":1},
                          "batch_wait":{"count":1,"p50_us":1,"p90_us":1,"p99_us":1},
                          "compute":{"count":1,"p50_us":1,"p90_us":1,"p99_us":1}}}"#,
        )
        .unwrap_err();
        assert!(err.contains("governor"), "{err}");
        // a governed leg that saved nothing is not a demonstration
        let cfg = BenchConfig {
            requests: 40,
            concurrency: 2,
            chips: 1,
            max_train: 120,
            governor: true,
            ..BenchConfig::smoke()
        };
        let mut report = run(&cfg).unwrap();
        report.governor.as_mut().unwrap().fj_saved = 0;
        let err = validate_bench_json(&report.to_json()).unwrap_err();
        assert!(err.contains("fj_saved"), "{err}");
    }

    #[test]
    fn gate_passes_within_budget_and_fails_beyond_it() {
        let report = |rps: f64, p99: u64| {
            format!(
                r#"{{"schema":"velm-bench-serve/1","dataset":"d","requests":10,
                    "responses":10,"elapsed_us":1000,"throughput_rps":{rps},
                    "conversions":10,"energy_fj":100,"macs":10,"pj_per_mac":0.1,
                    "stages":{{"total":{{"count":10,"p50_us":5,"p90_us":8,"p99_us":{p99}}},
                              "queue":{{"count":10,"p50_us":1,"p90_us":1,"p99_us":1}},
                              "batch_wait":{{"count":10,"p50_us":1,"p90_us":1,"p99_us":1}},
                              "compute":{{"count":10,"p50_us":1,"p90_us":1,"p99_us":1}}}}}}"#
            )
        };
        let prev = report(1000.0, 100);
        // small wobble inside the 10% budget: pass, both directions
        gate_bench_json(&report(950.0, 105), &prev, 0.10).unwrap();
        gate_bench_json(&report(1200.0, 50), &prev, 0.10).unwrap();
        // throughput collapse: fail, and the verdict names the axis
        let err = gate_bench_json(&report(800.0, 100), &prev, 0.10).unwrap_err();
        assert!(err.contains("throughput"), "{err}");
        // p99 blowup: fail
        let err = gate_bench_json(&report(1000.0, 125), &prev, 0.10).unwrap_err();
        assert!(err.contains("p99"), "{err}");
        // garbage inputs are named by side
        let err = gate_bench_json("not json", &prev, 0.10).unwrap_err();
        assert!(err.contains("current"), "{err}");
        let err = gate_bench_json(&prev, "{}", 0.10).unwrap_err();
        assert!(err.contains("previous"), "{err}");
    }
}
