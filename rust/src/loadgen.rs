//! Closed-loop serving load generator (`velm bench serve`, DESIGN.md
//! §16): boot a fleet in-process, hammer it from N closed-loop worker
//! threads (each waits for its answer before sending the next row),
//! then reduce the coordinator's own [`StatsSnapshot`] into a
//! versioned JSON benchmark report — the `BENCH_6.json` artifact CI
//! regenerates and schema-validates.
//!
//! The report deliberately reuses the observability layer instead of
//! measuring on its own: the per-stage percentiles come from the same
//! histograms `STATS` serves, and the energy figures from the same
//! ledger the workers price conversions into — so the benchmark also
//! exercises the telemetry path it reports through.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::{ChipConfig, SystemConfig, Transfer};
use crate::coordinator::Coordinator;
use crate::datasets::synth;
use crate::protocol::{StageStats, StatsSnapshot};
use crate::util::json::Value;

/// Schema tag stamped into every report; bump with the field set.
pub const BENCH_SCHEMA: &str = "velm-bench-serve/1";

/// One benchmark run's shape.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Synthetic dataset the fleet trains and serves on.
    pub dataset: String,
    pub seed: u64,
    /// Total rows to serve across all closed-loop workers.
    pub requests: usize,
    /// Closed-loop client threads.
    pub concurrency: usize,
    /// Dies in the fleet.
    pub chips: usize,
    /// Cap on the training set (0 = full) — smoke runs train fast.
    pub max_train: usize,
}

impl BenchConfig {
    /// The CI smoke shape: small enough for seconds, large enough to
    /// populate every stage histogram.
    pub fn smoke() -> BenchConfig {
        BenchConfig {
            dataset: "brightdata".into(),
            seed: 1,
            requests: 400,
            concurrency: 4,
            chips: 2,
            max_train: 200,
        }
    }

    /// The default (non-smoke) shape.
    pub fn full() -> BenchConfig {
        BenchConfig { requests: 4000, max_train: 0, ..BenchConfig::smoke() }
    }
}

/// What one run produced: wall-clock plus the coordinator's final
/// snapshot (stage histograms, energy ledger, counters).
#[derive(Clone, Debug)]
pub struct BenchReport {
    pub dataset: String,
    pub requests: u64,
    pub elapsed_us: u64,
    pub snapshot: StatsSnapshot,
}

impl BenchReport {
    /// Served rows per wall-clock second.
    pub fn throughput_rps(&self) -> f64 {
        if self.elapsed_us == 0 {
            0.0
        } else {
            self.snapshot.responses as f64 / (self.elapsed_us as f64 * 1e-6)
        }
    }

    /// Render the versioned JSON report ([`BENCH_SCHEMA`]).
    pub fn to_json(&self) -> String {
        let u = |n: u64| Value::Num(n as f64);
        let stage = |s: &StageStats| {
            Value::Obj(vec![
                ("count".into(), u(s.count)),
                ("p50_us".into(), u(s.p50_us)),
                ("p90_us".into(), u(s.p90_us)),
                ("p99_us".into(), u(s.p99_us)),
                ("mean_us".into(), Value::Num(s.mean_us())),
            ])
        };
        let s = &self.snapshot;
        let mut out = String::new();
        Value::Obj(vec![
            ("schema".into(), Value::Str(BENCH_SCHEMA.into())),
            ("dataset".into(), Value::Str(self.dataset.clone())),
            ("requests".into(), u(self.requests)),
            ("responses".into(), u(s.responses)),
            ("elapsed_us".into(), u(self.elapsed_us)),
            ("throughput_rps".into(), Value::Num(self.throughput_rps())),
            ("conversions".into(), u(s.conversions)),
            ("energy_fj".into(), u(s.energy_fj)),
            ("macs".into(), u(s.macs)),
            ("pj_per_mac".into(), Value::Num(s.pj_per_mac())),
            (
                "stages".into(),
                Value::Obj(vec![
                    ("total".into(), stage(&s.latency)),
                    ("queue".into(), stage(&s.queue)),
                    ("batch_wait".into(), stage(&s.batch_wait)),
                    ("compute".into(), stage(&s.compute)),
                ]),
            ),
        ])
        .write(&mut out);
        out
    }
}

/// Check a `BENCH_6.json` document against [`BENCH_SCHEMA`]: the tag,
/// every counter, the derived rates and all four stage blocks must be
/// present and self-consistent. CI runs this over the committed
/// artifact after regenerating it.
pub fn validate_bench_json(text: &str) -> Result<(), String> {
    let v = Value::parse(text)?;
    let schema = v.get("schema").and_then(Value::as_str).ok_or("missing 'schema'")?;
    if schema != BENCH_SCHEMA {
        return Err(format!("schema '{schema}' != '{BENCH_SCHEMA}'"));
    }
    v.get("dataset").and_then(Value::as_str).ok_or("missing 'dataset'")?;
    let u = |k: &str| v.get(k).and_then(Value::as_u64).ok_or(format!("missing '{k}'"));
    let f = |k: &str| {
        v.get(k)
            .and_then(Value::as_f64)
            .filter(|x| x.is_finite() && *x >= 0.0)
            .ok_or(format!("missing or non-finite '{k}'"))
    };
    let requests = u("requests")?;
    let responses = u("responses")?;
    if requests == 0 {
        return Err("a bench report must serve at least one request".into());
    }
    if responses > requests {
        return Err(format!("responses {responses} > requests {requests}"));
    }
    if u("elapsed_us")? == 0 {
        return Err("elapsed_us must be positive".into());
    }
    f("throughput_rps")?;
    f("pj_per_mac")?;
    u("conversions")?;
    u("energy_fj")?;
    u("macs")?;
    let stages = v.get("stages").ok_or("missing 'stages'")?;
    for key in ["total", "queue", "batch_wait", "compute"] {
        let s = stages.get(key).ok_or(format!("missing stage '{key}'"))?;
        let su = |k: &str| {
            s.get(k)
                .and_then(Value::as_u64)
                .ok_or(format!("stage '{key}' missing '{k}'"))
        };
        let count = su("count")?;
        let (p50, p99) = (su("p50_us")?, su("p99_us")?);
        su("p90_us")?;
        if count > 0 && p50 > p99 {
            return Err(format!("stage '{key}': p50 {p50} > p99 {p99}"));
        }
    }
    Ok(())
}

/// Boot a fleet per `cfg`, drive it closed-loop, return the report.
pub fn run(cfg: &BenchConfig) -> Result<BenchReport> {
    let mut ds = synth::by_name(&cfg.dataset, cfg.seed)
        .with_context(|| format!("unknown dataset {}", cfg.dataset))?;
    if cfg.max_train > 0 && ds.train_x.len() > cfg.max_train {
        ds.train_x.truncate(cfg.max_train);
        ds.train_y.truncate(cfg.max_train);
    }
    let sys = SystemConfig {
        n_chips: cfg.chips.max(1),
        max_wait: Duration::from_millis(1),
        seed: cfg.seed,
        artifact_dir: "/nonexistent".into(),
        ..SystemConfig::default()
    };
    let chip = ChipConfig::default()
        .with_dims(ds.d(), 24)
        .with_b(10)
        .with_mode(Transfer::Quadratic);
    let coord = Arc::new(Coordinator::start(&sys, &chip, &ds.train_x, &ds.train_y, 0.1, 10)?);
    let workers = cfg.concurrency.max(1);
    let per = (cfg.requests / workers).max(1);
    let xs = &ds.train_x;
    let t0 = Instant::now();
    std::thread::scope(|scope| -> Result<()> {
        let mut joins = Vec::new();
        for w in 0..workers {
            let coord = Arc::clone(&coord);
            joins.push(scope.spawn(move || -> Result<()> {
                for i in 0..per {
                    // closed loop: wait for the answer before the next row
                    coord.classify(xs[(w * per + i) % xs.len()].clone())?;
                }
                Ok(())
            }));
        }
        for j in joins {
            j.join().map_err(|_| anyhow::anyhow!("bench worker panicked"))??;
        }
        Ok(())
    })?;
    let elapsed_us = (t0.elapsed().as_micros() as u64).max(1);
    let snapshot = coord.snapshot();
    if let Ok(coord) = Arc::try_unwrap(coord) {
        coord.shutdown();
    }
    Ok(BenchReport {
        dataset: cfg.dataset.clone(),
        requests: (per * workers) as u64,
        elapsed_us,
        snapshot,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_run_produces_a_valid_self_consistent_report() {
        let cfg = BenchConfig {
            requests: 60,
            concurrency: 3,
            chips: 2,
            max_train: 120,
            ..BenchConfig::smoke()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.requests, 60);
        let s = &report.snapshot;
        assert_eq!(s.responses, 60, "closed-loop rows must all answer");
        assert_eq!(s.queue.count, 60);
        assert_eq!(s.batch_wait.count, 60);
        assert_eq!(s.compute.count, 60);
        assert!(s.energy_fj > 0, "served conversions must be priced");
        assert!(s.macs > 0);
        assert!(report.throughput_rps() > 0.0);
        validate_bench_json(&report.to_json()).unwrap();
    }

    #[test]
    fn validator_rejects_broken_reports() {
        assert!(validate_bench_json("not json").is_err());
        assert!(validate_bench_json("{}").is_err());
        let err = validate_bench_json(r#"{"schema":"wrong/9"}"#).unwrap_err();
        assert!(err.contains("schema"), "{err}");
        // a report whose counters contradict each other is refused
        let cfg = BenchConfig {
            requests: 20,
            concurrency: 2,
            chips: 1,
            max_train: 120,
            ..BenchConfig::smoke()
        };
        let mut report = run(&cfg).unwrap();
        report.snapshot.responses = report.requests + 5;
        let err = validate_bench_json(&report.to_json()).unwrap_err();
        assert!(err.contains("responses"), "{err}");
    }

    #[test]
    fn committed_bench_artifact_passes_the_schema() {
        // the repo-root BENCH_6.json is regenerated by CI via
        // `velm bench serve --smoke`; whatever is committed must parse
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_6.json");
        let text = std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("reading {path}: {e}"));
        validate_bench_json(&text).unwrap_or_else(|e| panic!("{path}: {e}"));
    }
}
