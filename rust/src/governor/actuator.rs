//! The governor's actuator: owns the [`Ladder`] and one [`DiePolicy`]
//! per die, walks every policy once per control tick, and applies the
//! resulting moves through a caller-supplied retune callback. The
//! coordinator wires that callback to `ControlMsg::Retune` on the
//! worker traffic channels; tests wire it to a closure — the actuator
//! itself never touches a channel, so every transition is
//! deterministic and unit-testable.

use crate::governor::policy::{Decision, DiePolicy, TickSignals};
use crate::governor::{GovernorConfig, Ladder};

/// What happened to one die on one tick.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MoveKind {
    /// Escalated toward the boot rung (hot traffic).
    Raised,
    /// Dropped one rung (idle, accuracy SLO holding).
    Lowered,
    /// A wanted move was refused (lifecycle or hysteresis), or the
    /// retune callback failed and the rung was rolled back.
    Rejected,
}

/// One applied (or refused) transition, for the flight recorder.
#[derive(Clone, Copy, Debug)]
pub struct Move {
    pub die: usize,
    pub kind: MoveKind,
    /// Counter bits after the move (current bits when rejected).
    pub b: u32,
    /// Conversion price at the new rung [fJ].
    pub price_fj: u64,
}

/// Per-fleet governor state: the ladder plus each die's policy.
#[derive(Clone, Debug)]
pub struct Actuator {
    cfg: GovernorConfig,
    ladder: Ladder,
    dies: Vec<DiePolicy>,
    pub ticks: u64,
    pub raises: u64,
    pub lowers: u64,
    pub rejected: u64,
}

impl Actuator {
    pub fn new(cfg: GovernorConfig, ladder: Ladder, n_dies: usize) -> Actuator {
        let boot = ladder.boot();
        Actuator {
            cfg,
            ladder,
            dies: (0..n_dies).map(|_| DiePolicy::new(boot)).collect(),
            ticks: 0,
            raises: 0,
            lowers: 0,
            rejected: 0,
        }
    }

    pub fn ladder(&self) -> &Ladder {
        &self.ladder
    }

    /// Counter bits each die currently runs at.
    pub fn points(&self) -> Vec<u32> {
        self.dies.iter().map(|d| self.ladder.rung(d.rung()).b).collect()
    }

    /// One control tick: decide per die, apply moves via `retune`.
    ///
    /// `retune(die, b)` must re-point die `die` at counter bits `b`
    /// and return `true` on success; on failure the die's rung is
    /// rolled back (the worker may be mid-shutdown) and the move is
    /// counted rejected. Returns the moves/rejections for recording.
    pub fn tick(
        &mut self,
        signals: &[TickSignals],
        mut retune: impl FnMut(usize, u32) -> bool,
    ) -> Vec<Move> {
        self.ticks += 1;
        let mut out = Vec::new();
        let (len, boot) = (self.ladder.len(), self.ladder.boot());
        for (die, policy) in self.dies.iter_mut().enumerate() {
            let sig = signals.get(die).copied().unwrap_or_default();
            let cur = |p: &DiePolicy, l: &Ladder| {
                let r = l.rung(p.rung());
                (r.b, r.price_fj)
            };
            match policy.decide(&self.cfg, len, boot, &sig) {
                Decision::Hold => {}
                Decision::Rejected(_) => {
                    self.rejected += 1;
                    let (b, price_fj) = cur(policy, &self.ladder);
                    out.push(Move { die, kind: MoveKind::Rejected, b, price_fj });
                }
                Decision::Raise { from, to } | Decision::Lower { from, to } => {
                    let raised = to > from;
                    let rung = self.ladder.rung(to);
                    if retune(die, rung.b) {
                        if raised {
                            self.raises += 1;
                        } else {
                            self.lowers += 1;
                        }
                        out.push(Move {
                            die,
                            kind: if raised { MoveKind::Raised } else { MoveKind::Lowered },
                            b: rung.b,
                            price_fj: rung.price_fj,
                        });
                    } else {
                        policy.revert(from);
                        self.rejected += 1;
                        let (b, price_fj) = cur(policy, &self.ladder);
                        out.push(Move { die, kind: MoveKind::Rejected, b, price_fj });
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;

    fn actuator(dies: usize) -> Actuator {
        let cfg = GovernorConfig {
            cooldown_ticks: 0,
            window_ticks: 100,
            max_moves_per_window: 100,
            ..GovernorConfig::default()
        };
        let ladder = Ladder::from_bits(&ChipConfig::default(), &[6, 8, 10]);
        Actuator::new(cfg, ladder, dies)
    }

    fn idle() -> TickSignals {
        TickSignals { healthy: true, accuracy_ok: true, ..TickSignals::default() }
    }

    #[test]
    fn applies_moves_through_the_callback_and_counts_them() {
        let mut a = actuator(2);
        assert_eq!(a.points(), vec![14, 14]);
        let mut applied = Vec::new();
        let moves = a.tick(&[idle(), idle()], |die, b| {
            applied.push((die, b));
            true
        });
        assert_eq!(applied, vec![(0, 10), (1, 10)]);
        assert_eq!(moves.len(), 2);
        assert!(moves.iter().all(|m| m.kind == MoveKind::Lowered));
        assert_eq!(a.points(), vec![10, 10]);
        assert_eq!((a.ticks, a.lowers, a.raises, a.rejected), (1, 2, 0, 0));
    }

    #[test]
    fn failed_retune_rolls_the_rung_back() {
        let mut a = actuator(1);
        let moves = a.tick(&[idle()], |_, _| false);
        assert_eq!(moves.len(), 1);
        assert_eq!(moves[0].kind, MoveKind::Rejected);
        assert_eq!(a.points(), vec![14], "rung restored after failure");
        assert_eq!(a.rejected, 1);
    }

    #[test]
    fn missing_signals_default_to_unhealthy_and_reject() {
        let mut a = actuator(2);
        // only one signal for two dies: die 1 defaults to !healthy
        let moves = a.tick(&[idle()], |_, _| true);
        assert_eq!(moves.len(), 2);
        assert_eq!(moves[1].kind, MoveKind::Rejected);
        assert_eq!(a.points()[1], 14);
    }
}
