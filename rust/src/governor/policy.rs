//! Pure per-die governor policy: decides, once per control tick,
//! whether a die should climb toward a high-throughput rung, drop to a
//! low-energy rung, or hold — bounded by a post-move cooldown and a
//! per-window move budget (hysteresis) so the loop cannot flap, and
//! always deferring to the fleet lifecycle (an unhealthy die is never
//! moved). No I/O and no clocks: the coordinator feeds it
//! [`TickSignals`] computed from stats-snapshot deltas.

use crate::governor::GovernorConfig;

/// What the governor observed about one die over the last tick.
#[derive(Clone, Copy, Debug, Default)]
pub struct TickSignals {
    /// Fleet lifecycle says the die is Healthy (routable). Anything
    /// else — Degraded, Draining, Recalibrating, Quarantined, Standby —
    /// and the governor must keep its hands off.
    pub healthy: bool,
    /// Rows submitted to the fleet since the previous tick.
    pub requests_delta: u64,
    /// Requests currently queued on this die's channel.
    pub outstanding: usize,
    /// Mean queue wait over the rows of the last tick [us].
    pub mean_queue_us: u64,
    /// Every tenant currently holds its accuracy SLO (training-set
    /// error at or under its `slo_max_err`); a die only drops to a
    /// cheaper, noisier rung while this is true.
    pub accuracy_ok: bool,
    /// The sliding-window p99 latency (fleet-wide against
    /// `GovernorConfig::p99_slo_us`, or any tenant's against its
    /// `slo_p99_us`) breached over the last tick: the die counts as
    /// hot regardless of traffic and never descends (DESIGN.md §19).
    pub slo_breach: bool,
}

/// Why a wanted move was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// Die is not Healthy; lifecycle owns it (probe/renorm/refit).
    Unhealthy,
    /// The per-window move budget is spent (hysteresis).
    Hysteresis,
}

/// One tick's verdict for one die. `from`/`to` are ladder rung indices.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    Hold,
    Raise { from: usize, to: usize },
    Lower { from: usize, to: usize },
    Rejected(RejectReason),
}

/// Per-die control state: current rung plus the anti-flap bookkeeping.
#[derive(Clone, Debug)]
pub struct DiePolicy {
    rung: usize,
    cooldown: u32,
    moves_in_window: u32,
    tick_in_window: u32,
}

impl DiePolicy {
    /// A die starts life on the fleet's boot (tuned) rung.
    pub fn new(boot_rung: usize) -> DiePolicy {
        DiePolicy { rung: boot_rung, cooldown: 0, moves_in_window: 0, tick_in_window: 0 }
    }

    /// Ladder rung the die currently occupies.
    pub fn rung(&self) -> usize {
        self.rung
    }

    /// Advance one tick and decide. `ladder_len` bounds the climb;
    /// `boot_rung` is the ceiling a hot die escalates back toward
    /// (raising above boot trades tuned accuracy for nothing — the
    /// boot point already met the latency objective when tuned).
    pub fn decide(
        &mut self,
        cfg: &GovernorConfig,
        ladder_len: usize,
        boot_rung: usize,
        sig: &TickSignals,
    ) -> Decision {
        // hysteresis window bookkeeping runs even on held ticks
        self.tick_in_window += 1;
        if self.tick_in_window >= cfg.window_ticks.max(1) {
            self.tick_in_window = 0;
            self.moves_in_window = 0;
        }
        if !sig.healthy {
            return Decision::Rejected(RejectReason::Unhealthy);
        }
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return Decision::Hold;
        }
        let top = boot_rung.min(ladder_len.saturating_sub(1));
        // a latency-SLO breach is hot on its own: rows already in the
        // histogram are late even if no new traffic arrived this tick
        let hot = sig.slo_breach
            || (sig.requests_delta > 0
                && (sig.mean_queue_us >= cfg.hot_queue_us || sig.outstanding > 0));
        let idle = sig.requests_delta == 0 && sig.outstanding == 0 && !sig.slo_breach;
        let want = if hot && self.rung < top {
            Some(Decision::Raise { from: self.rung, to: top })
        } else if idle && sig.accuracy_ok && self.rung > 0 {
            Some(Decision::Lower { from: self.rung, to: self.rung - 1 })
        } else {
            None
        };
        match want {
            None => Decision::Hold,
            Some(d) => {
                if self.moves_in_window >= cfg.max_moves_per_window {
                    return Decision::Rejected(RejectReason::Hysteresis);
                }
                self.moves_in_window += 1;
                self.cooldown = cfg.cooldown_ticks;
                self.rung = match d {
                    Decision::Raise { to, .. } | Decision::Lower { to, .. } => to,
                    _ => self.rung,
                };
                d
            }
        }
    }

    /// Roll back a move the actuator could not apply (worker gone):
    /// restore the rung but keep the cooldown, so a dead channel is
    /// not hammered every tick.
    pub fn revert(&mut self, to: usize) {
        self.rung = to;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> GovernorConfig {
        GovernorConfig {
            cooldown_ticks: 0,
            window_ticks: 100,
            max_moves_per_window: 100,
            ..GovernorConfig::default()
        }
    }

    fn idle() -> TickSignals {
        TickSignals { healthy: true, accuracy_ok: true, ..TickSignals::default() }
    }

    fn hot() -> TickSignals {
        TickSignals {
            healthy: true,
            accuracy_ok: true,
            requests_delta: 50,
            mean_queue_us: 10_000,
            ..TickSignals::default()
        }
    }

    #[test]
    fn idle_die_steps_down_one_rung_at_a_time() {
        let mut p = DiePolicy::new(3);
        assert_eq!(p.decide(&cfg(), 4, 3, &idle()), Decision::Lower { from: 3, to: 2 });
        assert_eq!(p.decide(&cfg(), 4, 3, &idle()), Decision::Lower { from: 2, to: 1 });
        assert_eq!(p.decide(&cfg(), 4, 3, &idle()), Decision::Lower { from: 1, to: 0 });
        // floor: nowhere further down to go
        assert_eq!(p.decide(&cfg(), 4, 3, &idle()), Decision::Hold);
        assert_eq!(p.rung(), 0);
    }

    #[test]
    fn hot_die_jumps_straight_back_to_boot() {
        let mut p = DiePolicy::new(3);
        for _ in 0..3 {
            p.decide(&cfg(), 4, 3, &idle());
        }
        assert_eq!(p.rung(), 0);
        assert_eq!(p.decide(&cfg(), 4, 3, &hot()), Decision::Raise { from: 0, to: 3 });
        // already at the ceiling: hot traffic holds there
        assert_eq!(p.decide(&cfg(), 4, 3, &hot()), Decision::Hold);
    }

    #[test]
    fn latency_slo_breach_is_hot_even_at_idle() {
        let mut p = DiePolicy::new(3);
        for _ in 0..3 {
            p.decide(&cfg(), 4, 3, &idle());
        }
        assert_eq!(p.rung(), 0);
        // zero traffic this tick, but the windowed p99 breached: the
        // die jumps straight back to boot...
        let sig = TickSignals { slo_breach: true, ..idle() };
        assert_eq!(p.decide(&cfg(), 4, 3, &sig), Decision::Raise { from: 0, to: 3 });
        // ...and holds there — a breach blocks any descent
        assert_eq!(p.decide(&cfg(), 4, 3, &sig), Decision::Hold);
        assert_eq!(p.rung(), 3);
    }

    #[test]
    fn accuracy_slo_blocks_the_descent() {
        let mut p = DiePolicy::new(2);
        let sig = TickSignals { accuracy_ok: false, ..idle() };
        assert_eq!(p.decide(&cfg(), 3, 2, &sig), Decision::Hold);
        assert_eq!(p.rung(), 2);
    }

    #[test]
    fn unhealthy_die_is_never_touched() {
        let mut p = DiePolicy::new(2);
        let sig = TickSignals { healthy: false, ..idle() };
        assert_eq!(p.decide(&cfg(), 3, 2, &sig), Decision::Rejected(RejectReason::Unhealthy));
        assert_eq!(p.rung(), 2);
    }

    #[test]
    fn cooldown_spaces_consecutive_moves() {
        let c = GovernorConfig { cooldown_ticks: 2, ..cfg() };
        let mut p = DiePolicy::new(3);
        assert!(matches!(p.decide(&c, 4, 3, &idle()), Decision::Lower { .. }));
        // two held ticks while the cooldown drains
        assert_eq!(p.decide(&c, 4, 3, &idle()), Decision::Hold);
        assert_eq!(p.decide(&c, 4, 3, &idle()), Decision::Hold);
        assert!(matches!(p.decide(&c, 4, 3, &idle()), Decision::Lower { .. }));
    }

    #[test]
    fn window_budget_rejects_excess_moves() {
        let c = GovernorConfig {
            cooldown_ticks: 0,
            window_ticks: 10,
            max_moves_per_window: 1,
            ..GovernorConfig::default()
        };
        let mut p = DiePolicy::new(5);
        assert!(matches!(p.decide(&c, 6, 5, &idle()), Decision::Lower { .. }));
        for _ in 0..8 {
            // window still open: budget spent, further moves rejected
            assert_eq!(p.decide(&c, 6, 5, &idle()), Decision::Rejected(RejectReason::Hysteresis));
        }
        // tick 10 closes the window and the budget refills
        assert!(matches!(p.decide(&c, 6, 5, &idle()), Decision::Lower { .. }));
    }
}
