//! Traffic-adaptive power/accuracy governor (DESIGN.md §17).
//!
//! The dse explorer picks ONE Pareto point at startup; the governor
//! makes the energy/accuracy trade live. It closes the loop from the
//! PR-6 telemetry (stats snapshot deltas, queue-wait, per-tenant
//! training error, fleet health) to per-die operating points: idle
//! dies drop to low-energy rungs (fewer counter bits, hence a shorter
//! counting window and a cheaper conversion), hot dies climb back to
//! high-throughput rungs. Moves are rate-limited by a cooldown and a
//! per-window move budget so the control loop never flaps, and the
//! governor always defers to the fleet lifecycle: a die that is not
//! Healthy is never retuned.
//!
//! Layering:
//! - [`Ladder`]: the runtime Pareto-front artifact — the sorted `b`
//!   rungs a die may occupy, each priced in fJ/conversion at the
//!   fleet's base operating point ([`crate::chip::energy`]).
//! - [`policy`]: pure per-die decision logic (hysteresis, cooldown,
//!   hot/idle classification). No I/O, fully unit-testable.
//! - [`actuator`]: walks every die's policy each tick and applies the
//!   resulting moves through a caller-supplied retune callback (the
//!   coordinator wires this to `ControlMsg::Retune`).

pub mod actuator;
pub mod policy;

pub use actuator::{Actuator, Move, MoveKind};
pub use policy::{Decision, DiePolicy, RejectReason, TickSignals};

use crate::chip::energy::conversion_price_fj;
use crate::config::ChipConfig;
use crate::dse::OperatingPoint;

/// Governor settings, carried on `SystemConfig` like `fleet`.
#[derive(Clone, Debug)]
pub struct GovernorConfig {
    /// Master switch; `velm serve --governor` flips it on.
    pub enabled: bool,
    /// Control-loop period.
    pub tick: std::time::Duration,
    /// Ticks a die must hold still after any move.
    pub cooldown_ticks: u32,
    /// Hysteresis window length, in ticks.
    pub window_ticks: u32,
    /// Max moves one die may make inside one window.
    pub max_moves_per_window: u32,
    /// Mean queue wait (us, over the last tick) above which the fleet
    /// counts as hot and dies escalate toward the boot rung and above.
    pub hot_queue_us: u64,
    /// Default accuracy SLO (training-set error ceiling) applied to
    /// tenants whose `TenantSpec` carries no `slo_max_err`; a lower
    /// rung is only taken while every tenant holds its ceiling.
    pub err_slo: f64,
    /// Default latency SLO (p99, us) for tenants without `slo_p99_us`.
    pub p99_slo_us: u64,
    /// Counter-bit rungs of the ladder when no tuned front is loaded.
    pub bits: Vec<u32>,
}

impl Default for GovernorConfig {
    fn default() -> Self {
        GovernorConfig {
            enabled: false,
            tick: std::time::Duration::from_millis(250),
            cooldown_ticks: 2,
            window_ticks: 8,
            max_moves_per_window: 2,
            hot_queue_us: 2_000,
            err_slo: 0.5,
            p99_slo_us: 50_000,
            bits: vec![6, 8, 10, 14],
        }
    }
}

/// One occupiable operating point: counter bits plus the conversion
/// price a die pays there (integer fJ, same pricing as the ledger).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Rung {
    pub b: u32,
    pub price_fj: u64,
}

/// The runtime Pareto-front artifact: rungs sorted by counter bits
/// (and therefore by energy — the counting window T_neu scales with
/// 2^b, eq. 19, so fewer bits is strictly cheaper per conversion).
#[derive(Clone, Debug)]
pub struct Ladder {
    rungs: Vec<Rung>,
    boot: usize,
}

impl Ladder {
    /// Build from explicit counter-bit rungs. The base config's own
    /// `b` is always included so every die has a home rung; rungs
    /// that price to zero fJ are dropped as degenerate.
    pub fn from_bits(base: &ChipConfig, bits: &[u32]) -> Ladder {
        let mut bs: Vec<u32> = bits.to_vec();
        bs.push(base.b);
        bs.sort_unstable();
        bs.dedup();
        let mut rungs: Vec<Rung> = bs
            .into_iter()
            .filter(|&b| (1..=31).contains(&b))
            .map(|b| Rung { b, price_fj: conversion_price_fj(&base.clone().with_b(b)) })
            .filter(|r| r.price_fj > 0)
            .collect();
        if rungs.is_empty() {
            // degenerate pricing (all-zero) still leaves a home rung
            rungs.push(Rung { b: base.b, price_fj: conversion_price_fj(base).max(1) });
        }
        let boot = rungs.iter().position(|r| r.b == base.b).unwrap_or(rungs.len() - 1);
        Ladder { rungs, boot }
    }

    /// Build from a tuned Pareto front (`velm tune --out` file parsed
    /// by [`OperatingPoint::parse_front`]): the front's distinct
    /// counter-bit values become the rungs. Falls back to the config
    /// default bits when the front collapses to a single point.
    pub fn from_front(base: &ChipConfig, front: &[OperatingPoint], fallback: &[u32]) -> Ladder {
        let bits: Vec<u32> = front.iter().map(|p| p.b).collect();
        if bits.iter().collect::<std::collections::BTreeSet<_>>().len() < 2 {
            Ladder::from_bits(base, fallback)
        } else {
            Ladder::from_bits(base, &bits)
        }
    }

    pub fn len(&self) -> usize {
        self.rungs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rungs.is_empty()
    }

    /// Index of the rung the fleet booted on (the tuned point).
    pub fn boot(&self) -> usize {
        self.boot
    }

    pub fn rung(&self, i: usize) -> Rung {
        self.rungs[i.min(self.rungs.len() - 1)]
    }

    pub fn rungs(&self) -> &[Rung] {
        &self.rungs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_rungs_are_sorted_and_priced_monotonically() {
        let base = ChipConfig::default(); // b = 14
        let l = Ladder::from_bits(&base, &[10, 6, 8]);
        let bs: Vec<u32> = l.rungs().iter().map(|r| r.b).collect();
        assert_eq!(bs, vec![6, 8, 10, 14], "base b joins and sorts");
        for w in l.rungs().windows(2) {
            assert!(
                w[0].price_fj < w[1].price_fj,
                "fewer counter bits must be strictly cheaper: {w:?}"
            );
        }
        assert_eq!(l.rung(l.boot()).b, 14, "boot rung is the fleet's tuned b");
    }

    #[test]
    fn ladder_from_front_uses_front_bits_and_falls_back_when_flat() {
        let base = ChipConfig::default().with_b(10);
        let p = |b: u32| OperatingPoint { b, ..OperatingPoint::default() };
        let l = Ladder::from_front(&base, &[p(6), p(10), p(6)], &[8, 12]);
        let bs: Vec<u32> = l.rungs().iter().map(|r| r.b).collect();
        assert_eq!(bs, vec![6, 10]);
        // a single-point front carries no trade-off: use the fallback
        let l = Ladder::from_front(&base, &[p(10)], &[8, 12]);
        let bs: Vec<u32> = l.rungs().iter().map(|r| r.b).collect();
        assert_eq!(bs, vec![8, 10, 12]);
    }

    #[test]
    fn ladder_clamps_out_of_range_rung_index() {
        let l = Ladder::from_bits(&ChipConfig::default(), &[8]);
        assert_eq!(l.rung(usize::MAX).b, 14);
    }
}
