//! Multi-objective scoring of one operating point — the "measure" stage
//! between explore and select. Validation error runs through the paper's
//! Section III-D fast simulation ([`FastSim`]); energy and timing come
//! from the Section IV models (`chip::energy`, `chip::timing`) on the
//! `ChipConfig` the operating point implies; serving latency/throughput
//! add the digital second stage and the batcher's fixed dispatch cost,
//! making the batch size a real trade-off axis (Ghaderi-style runtime
//! power/accuracy knob, here chosen offline per workload).

use crate::chip::{energy, timing};
use crate::config::ChipConfig;
use crate::datasets::Dataset;
use crate::dse::explorer::OperatingPoint;
use crate::dse::FastSim;
use crate::elm::train::misclassification;
use crate::util::mat::{ridge_solve, Mat};
use crate::util::prng::Prng;
use crate::util::stats;

/// Per-sample digital second-stage MAC time folded into serving latency
/// [s/MAC] (a 10-bit multiply-add per hidden unit at ~500 MHz).
pub const T_MAC_DIGITAL: f64 = 2e-9;

/// Fixed per-batch dispatch overhead of the serving pipeline [s]
/// (batcher wakeup + routing + response fan-out, measured order).
pub const T_BATCH_OVERHEAD: f64 = 20e-6;

/// Error reported when the ridge system is unsolvable at a point
/// (degenerate H). Large but finite so front normalisation stays sane.
pub const UNSOLVABLE_ERROR: f64 = 1e3;

/// The [`FastSim`] a given operating point implies (nominal K_neu/T_neu,
/// swept mismatch / ratio / counter bits).
pub fn fastsim_for(op: &OperatingPoint) -> FastSim {
    FastSim {
        sigma_vt: op.sigma_vt,
        ratio: op.ratio,
        b: op.b,
        ..FastSim::default()
    }
}

/// One scored design point. All fields are plain numbers so evaluations
/// are `Copy` and cache cheaply.
#[derive(Clone, Copy, Debug)]
pub struct Evaluation {
    pub point: OperatingPoint,
    /// Mean validation error over the objective's trials
    /// (misclassification rate for ±1 targets, RMSE for regression).
    pub error: f64,
    /// Section IV-C energy efficiency at this operating point [pJ/MAC].
    pub energy_pj_per_mac: f64,
    /// Modelled serving latency of one full batch [s].
    pub latency_s: f64,
    /// Modelled serving throughput [classifications/s].
    pub throughput_cps: f64,
}

impl Evaluation {
    /// Minimisation-oriented objective vector for the Pareto machinery:
    /// `[error, energy pJ/MAC, latency, -throughput]`.
    pub fn objectives(&self) -> [f64; 4] {
        [
            self.error,
            self.energy_pj_per_mac,
            self.latency_s,
            -self.throughput_cps,
        ]
    }
}

/// The workload-specific evaluator: fit on (a subsample of) the train
/// split through the fast chip simulation, score on the dataset's
/// `test_*` split, and read energy/timing off the Section IV models.
///
/// The `test_*` split is the tuner's **validation** set: whatever you
/// pass here steers operating-point selection. When you will report
/// final accuracy on a held-out test set afterwards, tune on a
/// `Dataset` whose `test_*` rows are carved out of the training data
/// instead (see `examples/autotune.rs`), or the selection leaks into
/// the reported number. (For the Fig. 7 sinc reproduction, scoring
/// against the clean-function targets *is* the paper's protocol.)
pub struct Objective<'a> {
    pub dataset: &'a Dataset,
    /// Independent dies (weight draws) averaged per point.
    pub trials: usize,
    /// Ridge constant for the validation fits.
    pub lambda: f64,
    /// Base seed: part of the cache key, so two objectives with
    /// different seeds never share evaluations.
    pub seed: u64,
    /// Score with misclassification (±1 targets) instead of RMSE.
    pub classification: bool,
    /// Training rows used per fit (subsampled deterministically).
    pub max_train: usize,
    /// Validation rows used per trial.
    pub max_val: usize,
    /// Fabricated die geometry `(k, N)` realising the point. When the
    /// workload dimension d or the point's L exceeds it, the Section V
    /// rotation serves the excess at `ceil(d/k) * ceil(L/N)` physical
    /// conversions per sample (DESIGN.md §13), priced into latency and
    /// energy — so the explorer can trade rotation passes against the
    /// accuracy a wider virtual L buys. `None` = dies are fabricated at
    /// the virtual dims (one pass, the pre-existing behaviour).
    ///
    /// Approximation: validation error still uses the fast simulation's
    /// i.i.d. d x L weight draw. The deployed rotation reuses one k x N
    /// matrix as rotated blocks (correlated columns) and accumulates
    /// the activation per chunk (`sum_c g(W_c x_c)`, the §6 caveat), so
    /// the modelled error is an optimistic bound on the rotated fleet's
    /// — see ROADMAP "Open items" for the rotation-faithful FastSim.
    pub phys: Option<(usize, usize)>,
}

impl<'a> Objective<'a> {
    /// Defaults: 600-row fits, 256-row validation, lambda 1e-4;
    /// classification auto-detected from the targets.
    pub fn new(dataset: &'a Dataset, trials: usize, seed: u64) -> Self {
        let classification = !dataset.train_y.is_empty()
            && dataset
                .train_y
                .iter()
                .all(|&y| (y - 1.0).abs() < 1e-9 || (y + 1.0).abs() < 1e-9);
        Objective {
            dataset,
            trials: trials.max(1),
            lambda: 1e-4,
            seed,
            classification,
            max_train: 600,
            max_val: 256,
            phys: None,
        }
    }

    /// Rotation passes one sample costs at this point (1 when the dims
    /// fit the fabricated die, or no die geometry is pinned).
    pub fn passes_for(&self, op: &OperatingPoint) -> usize {
        let d = self.dataset.d().max(1);
        let l = op.l.max(1);
        match self.phys {
            Some((k, n)) if k > 0 && n > 0 => d.div_ceil(k) * l.div_ceil(n),
            _ => 1,
        }
    }

    /// Whether the pinned die can realise this point at all — the
    /// Section V reuse bound (`RotationPlan::new`): d, L <= k*N.
    /// Infeasible points score [`UNSOLVABLE_ERROR`] so the explorer can
    /// never select a knee the fleet would refuse to serve.
    pub fn feasible(&self, op: &OperatingPoint) -> bool {
        match self.phys {
            Some((k, n)) => {
                let d = self.dataset.d().max(1);
                let l = op.l.max(1);
                d <= k * n && l <= k * n
            }
            None => true,
        }
    }

    /// Cache tag: the seed mixed with every objective setting that
    /// changes evaluation results, so a shared [`EvalCache`] can never
    /// alias two differently configured objectives (or workloads).
    ///
    /// [`EvalCache`]: crate::dse::cache::EvalCache
    pub fn cache_tag(&self) -> u64 {
        let mut tag = self.seed ^ 0x5EED_CAFE_F00D_D00D;
        let mut mix = |v: u64| {
            tag = (tag ^ v).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            tag ^= tag >> 29;
        };
        mix(self.lambda.to_bits());
        mix(self.trials as u64);
        mix(self.max_train as u64);
        mix(self.max_val as u64);
        mix(self.classification as u64);
        match self.phys {
            None => mix(0),
            Some((k, n)) => {
                mix(1);
                mix(k as u64);
                mix(n as u64);
            }
        }
        for b in self.dataset.name.bytes() {
            mix(b as u64);
        }
        mix(self.dataset.n_train() as u64);
        mix(self.dataset.n_test() as u64);
        // content fingerprint: name + shape alone would alias two
        // different generations of the same synthetic family (e.g.
        // sinc at two noise levels), so fold in sampled rows too
        let sample = |xs: &[Vec<f64>], ys: &[f64], mix: &mut dyn FnMut(u64)| {
            let n = xs.len();
            for k in [0, n / 3, n / 2, n.saturating_sub(1)] {
                if k < n {
                    for &v in xs[k].iter().take(4) {
                        mix(v.to_bits());
                    }
                    mix(ys[k].to_bits());
                }
            }
        };
        sample(&self.dataset.train_x, &self.dataset.train_y, &mut mix);
        sample(&self.dataset.test_x, &self.dataset.test_y, &mut mix);
        tag
    }

    /// One die: sample eq. 12 weights at the point's sigma_VT, push the
    /// fit split through eq. 11 counters, solve the ridge head, score on
    /// the validation split.
    fn trial_error(&self, op: &OperatingPoint, trial_seed: u64) -> f64 {
        let ds = self.dataset;
        let d = ds.d();
        if d == 0 || ds.n_test() == 0 {
            return UNSOLVABLE_ERROR;
        }
        let sim = fastsim_for(op);
        let mut rng = Prng::new(trial_seed ^ 0x0B1E_C7ED);
        let w = sim.sample_weights(d, op.l.max(1), &mut rng);
        let n = ds.n_train();
        let take = n.min(self.max_train.max(1));
        let idx: Vec<usize> = if take == n {
            (0..n).collect()
        } else {
            rng.permutation(n)[..take].to_vec()
        };
        let fit_x: Vec<Vec<f64>> = idx.iter().map(|&i| ds.train_x[i].clone()).collect();
        let fit_y: Vec<f64> = idx.iter().map(|&i| ds.train_y[i]).collect();
        // same O(1) activation scaling as the serving path (lambda parity)
        let scale = 1.0 / sim.cap();
        let mut h = sim.hidden(&fit_x, &w);
        h.scale(scale);
        let t = Mat { rows: fit_y.len(), cols: 1, data: fit_y };
        let beta = match ridge_solve(&h, &t, self.lambda) {
            Ok(b) => b,
            Err(_) => return UNSOLVABLE_ERROR,
        };
        // subsample (not truncate) the validation rows: test sets can be
        // ordered (sinc is ascending in x), and a prefix would score an
        // unrepresentative slice of the domain
        let n_test = ds.n_test();
        let m = n_test.min(self.max_val.max(1));
        let vidx: Vec<usize> = if m == n_test {
            (0..n_test).collect()
        } else {
            rng.permutation(n_test)[..m].to_vec()
        };
        let val_x: Vec<Vec<f64>> = vidx.iter().map(|&i| ds.test_x[i].clone()).collect();
        let val_y: Vec<f64> = vidx.iter().map(|&i| ds.test_y[i]).collect();
        let mut hv = sim.hidden(&val_x, &w);
        hv.scale(scale);
        let pred = hv.matmul(&beta);
        if self.classification {
            misclassification(&pred.col(0), &val_y)
        } else {
            stats::rmse(&pred.col(0), &val_y)
        }
    }

    /// Score one operating point on all objectives.
    pub fn evaluate(&self, op: &OperatingPoint) -> Evaluation {
        let error = if self.feasible(op) {
            let errs: Vec<f64> = (0..self.trials)
                .map(|t| self.trial_error(op, self.seed.wrapping_add(7919 * t as u64)))
                .collect();
            stats::mean(&errs)
        } else {
            UNSOLVABLE_ERROR
        };
        let d = self.dataset.d().max(1);
        let l = op.l.max(1);
        // the fabricated die: clamped to the physical geometry when the
        // point's dims outgrow it (the rotation serves the excess)
        let passes = self.passes_for(op);
        let (phys_d, phys_l) = match self.phys {
            Some((k, n)) if passes > 1 => (d.min(k), l.min(n)),
            _ => (d, l),
        };
        let mut cfg = ChipConfig::from_operating_point(op, phys_d);
        cfg.l = phys_l;
        // conversion time: mirror settling + counting window (eq. 19/20),
        // serialised over the rotation passes a virtual sample costs
        let t_conv = timing::t_c_design(&cfg) * passes as f64;
        // digital supply power at the mid-scale spike rate (half the
        // counter cap over one window), eq. 23 approximation
        let f_mid = 0.5 * cfg.cap() as f64 / cfg.t_neu();
        let p_total = energy::p_vdd_approx(cfg.l, f_mid, &cfg) + cfg.p_avdd;
        // energy per *virtual* MAC: the die burns power over all passes
        // while the sample's useful work stays d x L
        let energy_pj_per_mac = energy::pj_per_mac(p_total, t_conv, d, l);
        // serving model: one batch drains serially through the die, plus
        // the digital second stage per sample and a fixed dispatch cost
        let batch = op.batch.max(1) as f64;
        let t_digital = l as f64 * T_MAC_DIGITAL;
        let latency_s = T_BATCH_OVERHEAD + batch * (t_conv + t_digital);
        let throughput_cps = batch / latency_s;
        Evaluation {
            point: *op,
            error,
            energy_pj_per_mac,
            latency_s,
            throughput_cps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth;

    fn op(sigma_vt: f64, ratio: f64, b: u32, l: usize, batch: usize) -> OperatingPoint {
        OperatingPoint {
            sigma_vt,
            ratio,
            b,
            l,
            batch,
        }
    }

    #[test]
    fn classification_autodetected() {
        let cls = synth::brightdata(1);
        let reg = synth::sinc(100, 50, 0.2, 1);
        assert!(Objective::new(&cls, 1, 1).classification);
        assert!(!Objective::new(&reg, 1, 1).classification);
    }

    #[test]
    fn degenerate_sigma_scores_worse() {
        // sigma_VT -> 0 collapses the random features (Fig. 7a mechanism)
        let ds = synth::sinc(400, 128, 0.2, 2);
        let mut o = Objective::new(&ds, 2, 3);
        o.max_train = 300;
        let flat = o.evaluate(&op(0.0005, 0.75, 14, 64, 1));
        let good = o.evaluate(&op(0.020, 0.75, 14, 64, 1));
        assert!(
            flat.error > 1.5 * good.error,
            "flat {} good {}",
            flat.error,
            good.error
        );
    }

    #[test]
    fn energy_grows_with_counter_bits() {
        // T_neu doubles per bit at fixed spike rate -> more pJ/MAC
        let ds = synth::sinc(100, 50, 0.2, 4);
        let mut o = Objective::new(&ds, 1, 5);
        o.max_train = 80;
        let e8 = o.evaluate(&op(0.016, 0.75, 8, 32, 1));
        let e14 = o.evaluate(&op(0.016, 0.75, 14, 32, 1));
        assert!(
            e14.energy_pj_per_mac > e8.energy_pj_per_mac,
            "b=14 {} vs b=8 {}",
            e14.energy_pj_per_mac,
            e8.energy_pj_per_mac
        );
        assert!(e14.latency_s > e8.latency_s);
    }

    #[test]
    fn batch_trades_latency_for_throughput() {
        let ds = synth::sinc(100, 50, 0.2, 6);
        let mut o = Objective::new(&ds, 1, 7);
        o.max_train = 80;
        let b1 = o.evaluate(&op(0.016, 0.75, 10, 32, 1));
        let b64 = o.evaluate(&op(0.016, 0.75, 10, 32, 64));
        assert!(b64.latency_s > b1.latency_s);
        assert!(b64.throughput_cps > b1.throughput_cps);
        // identical chip physics: error and energy unchanged by batch
        assert_eq!(b1.error, b64.error);
        assert_eq!(b1.energy_pj_per_mac, b64.energy_pj_per_mac);
    }

    #[test]
    fn objectives_vector_orientation() {
        let ds = synth::sinc(100, 50, 0.2, 8);
        let mut o = Objective::new(&ds, 1, 9);
        o.max_train = 80;
        let e = o.evaluate(&op(0.016, 0.75, 10, 32, 16));
        let v = e.objectives();
        assert_eq!(v[0], e.error);
        assert_eq!(v[3], -e.throughput_cps);
        assert!(e.throughput_cps > 0.0 && e.latency_s > 0.0);
        assert!(e.energy_pj_per_mac > 0.0);
    }

    #[test]
    fn rotation_passes_price_latency_and_energy_not_error() {
        // brightdata is d=14; a 7x16 die serves L=32 via 2x2=4 rotation
        // passes: the error model is unchanged (FastSim's i.i.d. d x L
        // approximation — see the `phys` doc), conversion time is not
        let ds = synth::brightdata(3);
        let mut free = Objective::new(&ds, 1, 7);
        free.max_train = 120;
        let mut rotated = Objective::new(&ds, 1, 7);
        rotated.max_train = 120;
        rotated.phys = Some((7, 16));
        let p = op(0.016, 0.75, 10, 32, 8);
        assert_eq!(rotated.passes_for(&p), 4);
        assert_eq!(free.passes_for(&p), 1);
        let ef = free.evaluate(&p);
        let er = rotated.evaluate(&p);
        assert_eq!(ef.error, er.error, "rotation must not change the fit");
        assert!(
            er.latency_s > 2.0 * ef.latency_s,
            "passes not priced: free {} rotated {}",
            ef.latency_s,
            er.latency_s
        );
        assert!(er.throughput_cps < ef.throughput_cps);
        assert!(
            er.energy_pj_per_mac > ef.energy_pj_per_mac,
            "virtual MACs must cost more energy: free {} rotated {}",
            ef.energy_pj_per_mac,
            er.energy_pj_per_mac
        );
        // dims that fit the die are a single pass and price identically
        let fits = op(0.016, 0.75, 10, 16, 8);
        let mut within = Objective::new(&ds, 1, 7);
        within.max_train = 120;
        within.phys = Some((14, 16));
        assert_eq!(within.passes_for(&fits), 1);
    }

    #[test]
    fn infeasible_rotation_dims_score_unsolvable() {
        // a 2x4 die has k*N = 8 reusable weights; brightdata's d=14
        // cannot be rotated onto it (RotationPlan::new would refuse),
        // so the objective must poison the point instead of pricing it
        let ds = synth::brightdata(3);
        let mut o = Objective::new(&ds, 1, 7);
        o.max_train = 120;
        o.phys = Some((2, 4));
        let p = op(0.016, 0.75, 10, 8, 8);
        assert!(!o.feasible(&p));
        assert_eq!(o.evaluate(&p).error, UNSOLVABLE_ERROR);
        // and an L beyond k*N poisons even when d fits
        let mut o2 = Objective::new(&ds, 1, 7);
        o2.max_train = 120;
        o2.phys = Some((14, 4));
        let wide = op(0.016, 0.75, 10, 14 * 4 + 1, 8);
        assert!(!o2.feasible(&wide));
        assert_eq!(o2.evaluate(&wide).error, UNSOLVABLE_ERROR);
        // feasible points keep a real error
        assert!(o2.feasible(&op(0.016, 0.75, 10, 8, 8)));
    }

    #[test]
    fn phys_geometry_changes_the_cache_tag() {
        let ds = synth::sinc(100, 50, 0.2, 1);
        let a = Objective::new(&ds, 1, 9);
        let mut b = Objective::new(&ds, 1, 9);
        b.phys = Some((8, 32));
        assert_ne!(a.cache_tag(), b.cache_tag());
        let mut c = Objective::new(&ds, 1, 9);
        c.phys = Some((8, 64));
        assert_ne!(b.cache_tag(), c.cache_tag());
    }

    #[test]
    fn cache_tag_separates_workloads_and_settings() {
        // same synthetic family, same shape, different data -> new tag
        let a = synth::sinc(100, 50, 0.2, 1);
        let b = synth::sinc(100, 50, 0.3, 2);
        let oa = Objective::new(&a, 1, 9);
        let ob = Objective::new(&b, 1, 9);
        assert_ne!(oa.cache_tag(), ob.cache_tag());
        // deterministic for identical configuration
        let mut oc = Objective::new(&a, 1, 9);
        assert_eq!(oa.cache_tag(), oc.cache_tag());
        // any result-affecting setting changes the tag
        oc.lambda = 1.0;
        assert_ne!(oa.cache_tag(), oc.cache_tag());
    }

    #[test]
    fn evaluation_is_deterministic() {
        let ds = synth::sinc(200, 64, 0.2, 10);
        let mut o = Objective::new(&ds, 2, 11);
        o.max_train = 150;
        let a = o.evaluate(&op(0.016, 0.75, 10, 48, 4));
        let b = o.evaluate(&op(0.016, 0.75, 10, 48, 4));
        assert_eq!(a.error, b.error);
        assert_eq!(a.energy_pj_per_mac, b.energy_pj_per_mac);
    }
}
