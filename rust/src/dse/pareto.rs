//! Non-dominated front extraction and operating-point selection: the
//! "select" half of the autotuner loop. Objective vectors are
//! minimisation-oriented (the objective module negates throughput), the
//! front is the classic Pareto set, and two pickers turn a front into a
//! single deployable point: the knee (closest to the normalised ideal)
//! and a weighted scalarisation for callers with explicit priorities.

/// `a` Pareto-dominates `b`: no worse in every objective, strictly
/// better in at least one. All objectives are minimised.
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strictly_better = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strictly_better = true;
        }
    }
    strictly_better
}

/// Indices of the non-dominated rows of `objs` (O(n^2): fronts here are
/// hundreds of points, not millions). Duplicated points are all kept —
/// neither dominates the other.
pub fn front_indices(objs: &[Vec<f64>]) -> Vec<usize> {
    (0..objs.len())
        .filter(|&i| {
            !objs
                .iter()
                .enumerate()
                .any(|(j, o)| j != i && dominates(o, &objs[i]))
        })
        .collect()
}

/// Per-dimension (min, max) over the given rows.
fn bounds(objs: &[Vec<f64>], idx: &[usize]) -> (Vec<f64>, Vec<f64>) {
    let dims = objs[idx[0]].len();
    let mut lo = vec![f64::MAX; dims];
    let mut hi = vec![f64::MIN; dims];
    for &i in idx {
        for (k, &v) in objs[i].iter().enumerate() {
            lo[k] = lo[k].min(v);
            hi[k] = hi[k].max(v);
        }
    }
    (lo, hi)
}

/// Min-max normalise one row against front bounds; collapsed dimensions
/// (zero span) contribute 0 so they cannot skew distances.
fn normalised(o: &[f64], lo: &[f64], hi: &[f64]) -> Vec<f64> {
    o.iter()
        .enumerate()
        .map(|(k, &v)| {
            let span = hi[k] - lo[k];
            if span <= 0.0 {
                0.0
            } else {
                (v - lo[k]) / span
            }
        })
        .collect()
}

/// Knee point of the front: the member closest (L2, in normalised
/// objective space) to the ideal corner where every objective attains
/// its front-wide minimum. `None` iff `front` is empty.
pub fn knee_index(objs: &[Vec<f64>], front: &[usize]) -> Option<usize> {
    if front.is_empty() {
        return None;
    }
    let (lo, hi) = bounds(objs, front);
    front
        .iter()
        .copied()
        .min_by(|&a, &b| {
            let da: f64 = normalised(&objs[a], &lo, &hi).iter().map(|v| v * v).sum();
            let db: f64 = normalised(&objs[b], &lo, &hi).iter().map(|v| v * v).sum();
            da.partial_cmp(&db).unwrap_or(std::cmp::Ordering::Equal)
        })
}

/// "Pick for me": the front member minimising the weighted sum of
/// normalised objectives. Weights need not be normalised; a zero weight
/// makes that objective a don't-care. `None` iff `front` is empty.
pub fn select_weighted(objs: &[Vec<f64>], front: &[usize], weights: &[f64]) -> Option<usize> {
    if front.is_empty() {
        return None;
    }
    let (lo, hi) = bounds(objs, front);
    front.iter().copied().min_by(|&a, &b| {
        let score = |i: usize| -> f64 {
            normalised(&objs[i], &lo, &hi)
                .iter()
                .zip(weights)
                .map(|(v, w)| v * w)
                .sum()
        };
        score(a)
            .partial_cmp(&score(b))
            .unwrap_or(std::cmp::Ordering::Equal)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(rows: &[&[f64]]) -> Vec<Vec<f64>> {
        rows.iter().map(|r| r.to_vec()).collect()
    }

    #[test]
    fn dominance_basics() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0]));
        assert!(!dominates(&[1.0, 3.0], &[2.0, 2.0])); // trade-off
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0])); // equal: no strict edge
    }

    #[test]
    fn front_excludes_dominated_points() {
        // (3,3) is dominated by (1,2) and (2,1); the extremes survive.
        let objs = v(&[&[1.0, 2.0], &[2.0, 1.0], &[3.0, 3.0], &[0.5, 4.0]]);
        let front = front_indices(&objs);
        assert_eq!(front, vec![0, 1, 3]);
    }

    #[test]
    fn front_keeps_duplicates_and_single_point() {
        let objs = v(&[&[1.0, 1.0], &[1.0, 1.0], &[2.0, 2.0]]);
        assert_eq!(front_indices(&objs), vec![0, 1]);
        let one = v(&[&[5.0, 5.0]]);
        assert_eq!(front_indices(&one), vec![0]);
    }

    #[test]
    fn knee_prefers_the_elbow() {
        // L-shaped front: extremes are (0,10) and (10,0); (1,1) is the
        // obvious compromise.
        let objs = v(&[&[0.0, 10.0], &[10.0, 0.0], &[1.0, 1.0]]);
        let front = front_indices(&objs);
        assert_eq!(front.len(), 3);
        assert_eq!(knee_index(&objs, &front), Some(2));
    }

    #[test]
    fn knee_ignores_collapsed_dimensions() {
        // second objective identical everywhere: knee decided by the first
        let objs = v(&[&[3.0, 7.0], &[1.0, 7.0], &[2.0, 7.0]]);
        let front = front_indices(&objs);
        assert_eq!(knee_index(&objs, &front), Some(1));
    }

    #[test]
    fn weighted_selection_follows_weights() {
        let objs = v(&[&[0.0, 10.0], &[10.0, 0.0], &[4.0, 4.0]]);
        let front = front_indices(&objs);
        // care only about objective 0 -> pick its minimiser
        assert_eq!(select_weighted(&objs, &front, &[1.0, 0.0]), Some(0));
        // care only about objective 1
        assert_eq!(select_weighted(&objs, &front, &[0.0, 1.0]), Some(1));
        // balanced -> the compromise wins (0.4+0.4 < 1.0)
        assert_eq!(select_weighted(&objs, &front, &[1.0, 1.0]), Some(2));
    }

    #[test]
    fn empty_front_yields_none() {
        let objs: Vec<Vec<f64>> = vec![];
        assert_eq!(knee_index(&objs, &[]), None);
        assert_eq!(select_weighted(&objs, &[], &[1.0]), None);
    }
}
