//! Memoisation of design-point evaluations: the explorer's refinement
//! rounds revisit grid points (the shrunk region is seeded on the old
//! knee) and repeated tunes of the same workload re-ask the same
//! questions (share a cache via `Explorer::run_with_cache`), so
//! evaluations are cached under a *quantised* key — two floating-point
//! operating points that round to the same 0.1 mV / 1e-3-ratio cell
//! share one evaluation, while different workloads, seeds or objective
//! settings (the context tag) never do.

use std::collections::HashMap;

use crate::sync::{AtomicU64, Mutex, Ordering};

use crate::dse::explorer::OperatingPoint;
use crate::dse::objective::Evaluation;

/// Quantised operating point + evaluation-context tag: the cache key.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PointKey {
    /// sigma_VT in 0.1 mV steps.
    pub sigma_q: u32,
    /// Saturation ratio in 1e-3 steps.
    pub ratio_q: u32,
    pub b: u32,
    pub l: usize,
    pub batch: usize,
    /// Evaluation-context tag (`Objective::cache_tag`): different
    /// seeds, workloads or objective settings never share entries.
    pub tag: u64,
}

impl PointKey {
    pub fn quantize(op: &OperatingPoint, tag: u64) -> Self {
        PointKey {
            sigma_q: (op.sigma_vt * 1e4).round() as u32,
            ratio_q: (op.ratio * 1e3).round() as u32,
            b: op.b,
            l: op.l,
            batch: op.batch,
            tag,
        }
    }
}

/// Thread-safe evaluation memo with hit/miss counters. Shared by the
/// explorer's `par_map` workers: the map lock is held only for the
/// lookup and the insert, never during an evaluation, so concurrent
/// misses evaluate in parallel (a point raced by two workers is simply
/// computed twice — evaluations are deterministic, so both insert the
/// same value).
pub struct EvalCache {
    map: Mutex<HashMap<PointKey, Evaluation>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl EvalCache {
    pub fn new() -> Self {
        EvalCache {
            map: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    pub fn hits(&self) -> u64 {
        // relaxed-ok: independent hit/miss statistics; readers report
        // them individually and tolerate mid-sweep skew.
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        // relaxed-ok: see `hits`.
        self.misses.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Return the cached evaluation for `op` under the given context
    /// tag ([`Objective::cache_tag`](crate::dse::Objective::cache_tag)
    /// in the explorer), or compute it with `f` (outside the lock) and
    /// remember it.
    pub fn get_or_eval(
        &self,
        op: &OperatingPoint,
        tag: u64,
        f: impl FnOnce(&OperatingPoint) -> Evaluation,
    ) -> Evaluation {
        let key = PointKey::quantize(op, tag);
        if let Some(e) = self.map.lock().unwrap().get(&key) {
            // relaxed-ok: statistics counters only (see `hits`).
            self.hits.fetch_add(1, Ordering::Relaxed);
            return *e;
        }
        // relaxed-ok: statistics counter only (see `hits`).
        self.misses.fetch_add(1, Ordering::Relaxed);
        let e = f(op);
        self.map.lock().unwrap().insert(key, e);
        e
    }
}

impl Default for EvalCache {
    fn default() -> Self {
        EvalCache::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op(sigma_mv: f64) -> OperatingPoint {
        OperatingPoint {
            sigma_vt: sigma_mv * 1e-3,
            ratio: 0.75,
            b: 10,
            l: 64,
            batch: 1,
        }
    }

    fn fake_eval(p: &OperatingPoint, error: f64) -> Evaluation {
        Evaluation {
            point: *p,
            error,
            energy_pj_per_mac: 1.0,
            latency_s: 1e-4,
            throughput_cps: 1e4,
        }
    }

    #[test]
    fn second_lookup_is_a_hit_and_skips_eval() {
        let cache = EvalCache::new();
        let mut calls = 0;
        let p = op(16.0);
        let a = cache.get_or_eval(&p, 1, |q| {
            calls += 1;
            fake_eval(q, 0.1)
        });
        let b = cache.get_or_eval(&p, 1, |q| {
            calls += 1;
            fake_eval(q, 0.9) // would differ if recomputed
        });
        assert_eq!(calls, 1);
        assert_eq!(a.error, b.error);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn quantisation_merges_nearby_points() {
        let cache = EvalCache::new();
        // 16.00 mV and 16.02 mV round to the same 0.1 mV cell
        cache.get_or_eval(&op(16.00), 1, |q| fake_eval(q, 0.1));
        cache.get_or_eval(&op(16.02), 1, |q| fake_eval(q, 0.2));
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.hits(), 1);
        // 16.3 mV is a different cell
        cache.get_or_eval(&op(16.3), 1, |q| fake_eval(q, 0.3));
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn seed_and_discrete_axes_separate_keys() {
        let cache = EvalCache::new();
        let p = op(16.0);
        cache.get_or_eval(&p, 1, |q| fake_eval(q, 0.1));
        cache.get_or_eval(&p, 2, |q| fake_eval(q, 0.1));
        let mut p2 = p;
        p2.b = 8;
        cache.get_or_eval(&p2, 1, |q| fake_eval(q, 0.1));
        let mut p3 = p;
        p3.batch = 64;
        cache.get_or_eval(&p3, 1, |q| fake_eval(q, 0.1));
        assert_eq!(cache.len(), 4);
        assert_eq!(cache.hits(), 0);
    }

    #[test]
    fn concurrent_access_from_par_map() {
        let cache = EvalCache::new();
        let points: Vec<OperatingPoint> = (0..64).map(|k| op(5.0 + (k % 8) as f64)).collect();
        let out = crate::dse::par_map(points, 8, |p| {
            cache.get_or_eval(&p, 9, |q| fake_eval(q, q.sigma_vt))
        });
        assert_eq!(out.len(), 64);
        // 8 distinct sigma cells; racing workers may compute a cell twice
        // but the cache never grows past the distinct-key count
        assert_eq!(cache.len(), 8);
        assert_eq!(cache.hits() + cache.misses(), 64);
        assert!(cache.misses() >= 8);
    }
}
