//! The "explore" half of the autotuner: a grid sweep over the Fig. 7
//! design axes (sigma_VT × I_sat/I_max ratio × counter bits b × hidden
//! width L × serving batch size) with adaptive refinement — after each
//! round the continuous axes (sigma_VT, ratio) shrink around the current
//! knee point, so later rounds spend their evaluations near the optimum.
//! Evaluations run through [`par_map`](crate::dse::par_map) and are
//! memoised in an [`EvalCache`], making refinement overlap and repeated
//! tunes free.

use std::fmt;

use crate::dse::cache::{EvalCache, PointKey};
use crate::dse::objective::{Evaluation, Objective};
use crate::dse::{par_map, pareto};

/// One candidate configuration of chip + serving stack: everything the
/// design-space exploration is allowed to choose. Flows into
/// `ChipConfig::from_operating_point` and `Coordinator::start_tuned`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OperatingPoint {
    /// Threshold-voltage mismatch sigma [V] (Fig. 7a sweep: 5–45 mV;
    /// a *design* choice through transistor sizing, paper Section III).
    pub sigma_vt: f64,
    /// I_sat^z / I_max^z saturation ratio (Fig. 7a optimum ~0.75).
    pub ratio: f64,
    /// Counter bits b (Fig. 7c: 6–14).
    pub b: u32,
    /// Hidden-layer width L (physical or rotation-extended).
    pub l: usize,
    /// Serving batch size handed to the coordinator's dynamic batcher.
    pub batch: usize,
}

impl OperatingPoint {
    /// Serialise as the `key = value` subset `ChipConfig::from_kv`
    /// understands (plus the serving-side `batch`).
    pub fn to_kv(&self) -> String {
        format!(
            "sigma_vt = {}\nsat_ratio = {}\nb = {}\nl = {}\nbatch = {}\n",
            self.sigma_vt, self.ratio, self.b, self.l, self.batch
        )
    }

    /// Parse the `to_kv` format back (unknown keys are errors).
    /// Comment/section handling is shared with `ChipConfig::from_kv`
    /// ([`kv_lines`](crate::config::kv_lines)), and later sections
    /// override earlier ones — so parsing a whole `velm tune --out`
    /// file yields its final `[selected]` section.
    pub fn from_kv(text: &str) -> Result<Self, String> {
        let mut op = OperatingPoint::default();
        let mut any_key = false;
        for item in crate::config::kv_lines(text) {
            let (lineno, k, v) = item?;
            let fv = || -> Result<f64, String> {
                v.parse::<f64>()
                    .map_err(|e| format!("line {lineno}: bad value {v}: {e}"))
            };
            match k {
                "sigma_vt" => op.sigma_vt = fv()?,
                "sat_ratio" => op.ratio = fv()?,
                "b" => op.b = fv()? as u32,
                "l" => op.l = fv()? as usize,
                "batch" => op.batch = fv()? as usize,
                other => return Err(format!("line {lineno}: unknown key {other}")),
            }
            any_key = true;
        }
        if !any_key {
            // an empty / comments-only / headers-only file almost
            // certainly isn't the point the caller meant to load
            return Err("no operating-point keys found".into());
        }
        Ok(op)
    }

    /// Parse every `[front.N]` section of a `velm tune --out` file into
    /// its own point, in file order. This is how the Pareto front
    /// becomes a *runtime* artifact: the governor loads the whole front
    /// (not just the `[selected]` point `from_kv` yields) and uses its
    /// counter-bit spread as the die operating-point ladder.
    pub fn parse_front(text: &str) -> Result<Vec<OperatingPoint>, String> {
        let mut front = Vec::new();
        let mut section: Option<String> = None; // body of an open [front.N]
        let flush = |sec: &mut Option<String>, front: &mut Vec<OperatingPoint>| {
            if let Some(body) = sec.take() {
                front.push(OperatingPoint::from_kv(&body)?);
            }
            Ok::<(), String>(())
        };
        for raw in text.lines() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.starts_with('[') {
                flush(&mut section, &mut front)?;
                if line.starts_with("[front.") {
                    section = Some(String::new());
                }
            } else if let Some(body) = section.as_mut() {
                body.push_str(raw);
                body.push('\n');
            }
        }
        flush(&mut section, &mut front)?;
        if front.is_empty() {
            return Err("no [front.N] sections found".into());
        }
        Ok(front)
    }
}

impl Default for OperatingPoint {
    /// Table I nominals (also the `from_kv` fall-back values).
    fn default() -> Self {
        OperatingPoint { sigma_vt: 0.016, ratio: 0.75, b: 14, l: 128, batch: 1 }
    }
}

impl fmt::Display for OperatingPoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "sigma_VT={:.1} mV, ratio={:.3}, b={}, L={}, batch={}",
            self.sigma_vt * 1e3,
            self.ratio,
            self.b,
            self.l,
            self.batch
        )
    }
}

/// The searchable region: continuous ranges for sigma_VT and the
/// saturation ratio (gridded `*_steps` wide per round), explicit grids
/// for the discrete axes. Defaults mirror the paper's Fig. 7 sweeps.
#[derive(Clone, Debug)]
pub struct SearchSpace {
    /// sigma_VT range [V] (Fig. 7a sweeps 5–45 mV).
    pub sigma_vt: (f64, f64),
    /// I_sat^z/I_max^z range (Fig. 7a sweeps 0.1–2.5; the extremes are
    /// never competitive, so the default clips to the active region).
    pub ratio: (f64, f64),
    /// Grid points per round on the sigma axis (endpoints included).
    pub sigma_steps: usize,
    /// Grid points per round on the ratio axis (endpoints included).
    pub ratio_steps: usize,
    /// Counter-bit candidates.
    pub b: Vec<u32>,
    /// Hidden-width candidates.
    pub l: Vec<usize>,
    /// Serving batch-size candidates.
    pub batch: Vec<usize>,
}

impl Default for SearchSpace {
    fn default() -> Self {
        SearchSpace {
            sigma_vt: (0.005, 0.045),
            ratio: (0.25, 1.5),
            sigma_steps: 5,
            ratio_steps: 4,
            b: vec![6, 8, 10, 14],
            l: vec![32, 64, 128],
            batch: vec![1, 16, 64],
        }
    }
}

impl SearchSpace {
    /// Candidate count of one round's grid.
    pub fn grid_size(&self) -> usize {
        self.sigma_steps.max(1)
            * self.ratio_steps.max(1)
            * self.b.len()
            * self.l.len()
            * self.batch.len()
    }
}

/// Search region of one refinement round (continuous axes only).
#[derive(Clone, Copy, Debug)]
pub struct RegionSnapshot {
    pub sigma_lo: f64,
    pub sigma_hi: f64,
    pub ratio_lo: f64,
    pub ratio_hi: f64,
}

impl RegionSnapshot {
    pub fn sigma_span(&self) -> f64 {
        self.sigma_hi - self.sigma_lo
    }

    pub fn ratio_span(&self) -> f64 {
        self.ratio_hi - self.ratio_lo
    }
}

/// Inclusive linear grid over `[lo, hi]` with `n` points (n=1 -> midpoint).
fn linspace(lo: f64, hi: f64, n: usize) -> Vec<f64> {
    let n = n.max(1);
    if n == 1 {
        return vec![0.5 * (lo + hi)];
    }
    (0..n)
        .map(|k| lo + (hi - lo) * k as f64 / (n - 1) as f64)
        .collect()
}

/// Everything one `run()` produced: the evaluated points, the front, the
/// knee, the per-round search regions (shrinking — the refinement
/// audit trail) and the cache counters.
#[derive(Clone, Debug)]
pub struct ExploreResult {
    /// All distinct evaluated points, in evaluation order.
    pub evals: Vec<Evaluation>,
    /// The non-dominated subset of `evals`.
    pub front: Vec<Evaluation>,
    /// Knee of the front (None only when the space was empty).
    pub knee: Option<Evaluation>,
    /// Search region at the start of each round.
    pub regions: Vec<RegionSnapshot>,
    /// Cache counters — cumulative when a shared cache was passed to
    /// [`Explorer::run_with_cache`].
    pub cache_hits: u64,
    pub cache_misses: u64,
}

impl ExploreResult {
    /// "Pick for me" with explicit priorities over
    /// `[error, energy, latency, -throughput]` (see
    /// [`Evaluation::objectives`]). Scalarises over the already
    /// extracted front — any weighting's optimum is a front member.
    pub fn select(&self, weights: &[f64; 4]) -> Option<Evaluation> {
        let objs: Vec<Vec<f64>> = self.front.iter().map(|e| e.objectives().to_vec()).collect();
        let idx: Vec<usize> = (0..objs.len()).collect();
        pareto::select_weighted(&objs, &idx, weights).map(|i| self.front[i])
    }
}

/// The closed-loop explorer: grid → evaluate (parallel, memoised) →
/// front → knee → shrink region → repeat.
pub struct Explorer<'a> {
    pub space: SearchSpace,
    pub objective: Objective<'a>,
    /// Refinement rounds (1 = plain grid sweep).
    pub rounds: usize,
    /// Worker threads for the evaluation fan-out.
    pub threads: usize,
}

impl Explorer<'_> {
    /// Run the exploration with a fresh per-run cache. Deterministic
    /// for a fixed objective seed. Refinement rounds share the cache;
    /// to also make *repeated tunes* free, hold an [`EvalCache`]
    /// yourself and call [`run_with_cache`](Explorer::run_with_cache).
    pub fn run(&self) -> ExploreResult {
        self.run_with_cache(&EvalCache::new())
    }

    /// Run the exploration against a caller-owned cache, so successive
    /// tunes of the same workload (same objective settings and seed —
    /// enforced by [`Objective::cache_tag`] inside the key) skip every
    /// previously evaluated point.
    pub fn run_with_cache(&self, cache: &EvalCache) -> ExploreResult {
        let tag = self.objective.cache_tag();
        let mut evals: Vec<Evaluation> = Vec::new();
        let mut seen: std::collections::HashSet<PointKey> = std::collections::HashSet::new();
        let mut regions: Vec<RegionSnapshot> = Vec::new();
        let (mut s_lo, mut s_hi) = self.space.sigma_vt;
        let (mut r_lo, mut r_hi) = self.space.ratio;
        let rounds = self.rounds.max(1);
        for round in 0..rounds {
            regions.push(RegionSnapshot {
                sigma_lo: s_lo,
                sigma_hi: s_hi,
                ratio_lo: r_lo,
                ratio_hi: r_hi,
            });
            let mut candidates: Vec<OperatingPoint> = Vec::new();
            for &s in &linspace(s_lo, s_hi, self.space.sigma_steps) {
                for &r in &linspace(r_lo, r_hi, self.space.ratio_steps) {
                    for &b in &self.space.b {
                        for &l in &self.space.l {
                            for &batch in &self.space.batch {
                                candidates.push(OperatingPoint {
                                    sigma_vt: s,
                                    ratio: r,
                                    b,
                                    l,
                                    batch,
                                });
                            }
                        }
                    }
                }
            }
            let objective = &self.objective;
            let round_evals = par_map(candidates, self.threads.max(1), move |op| {
                cache.get_or_eval(&op, tag, |p| objective.evaluate(p))
            });
            for e in round_evals {
                // refinement rounds (and prior runs through a shared
                // cache) revisit grid points; keep one copy
                if seen.insert(PointKey::quantize(&e.point, tag)) {
                    evals.push(e);
                }
            }
            // shrink the continuous axes around the current knee: halve
            // the span, clamp to the original space. The last round's
            // shrink would never be used — skip its dominance pass.
            if round + 1 == rounds {
                break;
            }
            let objs: Vec<Vec<f64>> = evals.iter().map(|e| e.objectives().to_vec()).collect();
            let front = pareto::front_indices(&objs);
            if let Some(k) = pareto::knee_index(&objs, &front) {
                let knee = evals[k].point;
                let s_half = 0.25 * (s_hi - s_lo);
                let r_half = 0.25 * (r_hi - r_lo);
                s_lo = (knee.sigma_vt - s_half).max(self.space.sigma_vt.0);
                s_hi = (knee.sigma_vt + s_half).min(self.space.sigma_vt.1);
                r_lo = (knee.ratio - r_half).max(self.space.ratio.0);
                r_hi = (knee.ratio + r_half).min(self.space.ratio.1);
            }
        }
        let objs: Vec<Vec<f64>> = evals.iter().map(|e| e.objectives().to_vec()).collect();
        let front_idx = pareto::front_indices(&objs);
        let front: Vec<Evaluation> = front_idx.iter().map(|&i| evals[i]).collect();
        let knee = pareto::knee_index(&objs, &front_idx).map(|i| evals[i]);
        ExploreResult {
            evals,
            front,
            knee,
            regions,
            cache_hits: cache.hits(),
            cache_misses: cache.misses(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth;
    use crate::dse::objective::Objective;

    fn tiny_space() -> SearchSpace {
        SearchSpace {
            sigma_vt: (0.005, 0.045),
            ratio: (0.75, 0.75),
            sigma_steps: 3,
            ratio_steps: 1,
            b: vec![10],
            l: vec![24],
            batch: vec![1, 8],
        }
    }

    fn tiny_objective(ds: &crate::datasets::Dataset) -> Objective<'_> {
        let mut o = Objective::new(ds, 1, 7);
        o.max_train = 120;
        o
    }

    #[test]
    fn linspace_endpoints_and_midpoint() {
        assert_eq!(linspace(0.0, 1.0, 3), vec![0.0, 0.5, 1.0]);
        assert_eq!(linspace(2.0, 4.0, 1), vec![3.0]);
        let g = linspace(0.005, 0.045, 5);
        assert_eq!(g.len(), 5);
        assert!((g[0] - 0.005).abs() < 1e-15 && (g[4] - 0.045).abs() < 1e-15);
    }

    #[test]
    fn operating_point_kv_round_trip() {
        let op = OperatingPoint {
            sigma_vt: 0.02,
            ratio: 0.6,
            b: 8,
            l: 96,
            batch: 32,
        };
        let parsed = OperatingPoint::from_kv(&op.to_kv()).unwrap();
        assert_eq!(parsed, op);
        assert!(OperatingPoint::from_kv("junk = 1").is_err());
        assert!(OperatingPoint::from_kv("no-equals-here").is_err());
        // an empty or headers/comments-only file is an error, not the
        // silent default point
        assert!(OperatingPoint::from_kv("").is_err());
        assert!(OperatingPoint::from_kv("# note\n[selected]\n").is_err());
        // a `velm tune --out` style file parses to its last section
        let other = OperatingPoint { sigma_vt: 0.01, ratio: 1.0, b: 6, l: 8, batch: 2 };
        let file = format!(
            "# front then selected\n[front.0]\n{}\n[selected]\n{}",
            other.to_kv(),
            op.to_kv()
        );
        assert_eq!(OperatingPoint::from_kv(&file).unwrap(), op);
    }

    #[test]
    fn parse_front_yields_every_front_section_in_order() {
        let a = OperatingPoint { sigma_vt: 0.01, ratio: 1.0, b: 6, l: 8, batch: 2 };
        let b = OperatingPoint { sigma_vt: 0.02, ratio: 0.5, b: 12, l: 16, batch: 4 };
        let sel = OperatingPoint::default();
        let file = format!(
            "# tune output\n[front.0]\n{}\n[front.1]\n{}\n[selected]\n{}",
            a.to_kv(),
            b.to_kv(),
            sel.to_kv()
        );
        assert_eq!(OperatingPoint::parse_front(&file).unwrap(), vec![a, b]);
        // the [selected] section alone carries no front
        let err = OperatingPoint::parse_front(&format!("[selected]\n{}", sel.to_kv()));
        assert!(err.unwrap_err().contains("front"));
        // a bad key inside a front section is a loud error
        assert!(OperatingPoint::parse_front("[front.0]\nbogus = 1\n").is_err());
    }

    #[test]
    fn refinement_shrinks_search_region() {
        let ds = synth::sinc(200, 64, 0.2, 3);
        let ex = Explorer {
            space: tiny_space(),
            objective: tiny_objective(&ds),
            rounds: 3,
            threads: 2,
        };
        let r = ex.run();
        assert_eq!(r.regions.len(), 3);
        for w in r.regions.windows(2) {
            assert!(
                w[1].sigma_span() < w[0].sigma_span(),
                "sigma region did not shrink: {:?}",
                r.regions
            );
            assert!(w[1].sigma_lo >= tiny_space().sigma_vt.0 - 1e-12);
            assert!(w[1].sigma_hi <= tiny_space().sigma_vt.1 + 1e-12);
        }
    }

    #[test]
    fn front_non_empty_and_within_space() {
        let ds = synth::sinc(200, 64, 0.2, 4);
        let ex = Explorer {
            space: tiny_space(),
            objective: tiny_objective(&ds),
            rounds: 2,
            threads: 2,
        };
        let r = ex.run();
        assert!(!r.front.is_empty());
        assert!(r.knee.is_some());
        for e in &r.front {
            assert!(e.point.sigma_vt >= 0.005 - 1e-12 && e.point.sigma_vt <= 0.045 + 1e-12);
            assert_eq!(e.point.b, 10);
        }
        // refinement revisits the knee's grid point -> cache hits
        assert!(r.cache_hits > 0, "expected cache hits across rounds");
        // evals are distinct points
        let mut keys: Vec<_> = r
            .evals
            .iter()
            .map(|e| PointKey::quantize(&e.point, 7))
            .collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), r.evals.len());
    }

    #[test]
    fn shared_cache_makes_repeated_tunes_free() {
        let ds = synth::sinc(200, 64, 0.2, 6);
        let ex = Explorer {
            space: tiny_space(),
            objective: tiny_objective(&ds),
            rounds: 2,
            threads: 2,
        };
        let cache = EvalCache::new();
        let r1 = ex.run_with_cache(&cache);
        let (h1, m1) = (r1.cache_hits, r1.cache_misses);
        let r2 = ex.run_with_cache(&cache);
        // second tune evaluates nothing new and reproduces the result
        assert_eq!(r2.cache_misses, m1, "repeat tune recomputed points");
        assert!(r2.cache_hits > h1);
        assert_eq!(r1.evals.len(), r2.evals.len());
        assert_eq!(r1.knee.map(|k| k.point), r2.knee.map(|k| k.point));
        // a differently configured objective must NOT share entries
        let mut other = tiny_objective(&ds);
        other.lambda *= 10.0;
        let ex2 = Explorer { space: tiny_space(), objective: other, rounds: 1, threads: 2 };
        let before = cache.len();
        ex2.run_with_cache(&cache);
        assert!(cache.len() > before, "different lambda aliased cached evals");
    }

    #[test]
    fn select_honours_weights() {
        let ds = synth::sinc(200, 64, 0.2, 5);
        let ex = Explorer {
            space: tiny_space(),
            objective: tiny_objective(&ds),
            rounds: 1,
            threads: 2,
        };
        let r = ex.run();
        // batch 1 and batch 8 trade latency against throughput; weighting
        // one or the other must flip the selection's batch
        let fast = r.select(&[0.0, 0.0, 1.0, 0.0]).expect("latency pick");
        let wide = r.select(&[0.0, 0.0, 0.0, 1.0]).expect("throughput pick");
        assert_eq!(fast.point.batch, 1);
        assert_eq!(wide.point.batch, 8);
    }
}
