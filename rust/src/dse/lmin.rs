//! Fig. 7(a): L_min — the smallest hidden-layer size reaching a target
//! regression error — as a function of the I_sat^z/I_max^z ratio and the
//! mismatch sigma_VT. The paper's key design-space result: the optimum
//! ratio sits near 0.75 and sigma_VT in 15-25 mV minimises L_min.

use crate::dse::FastSim;
use crate::util::mat::{ridge_solve, Mat};
use crate::util::prng::Prng;
use crate::util::stats;

/// One regression trial: fit the sinc task through the fast chip
/// simulation with L hidden neurons; returns test RMSE vs the clean
/// function (the paper's d=1 noisy-samples regression, Section III-D).
pub fn regression_error(sim: &FastSim, l: usize, n_train: usize, seed: u64) -> f64 {
    let ds = crate::datasets::synth::sinc(n_train, 256, 0.2, seed);
    let mut rng = Prng::new(seed ^ 0x11F0);
    let w = sim.sample_weights(1, l, &mut rng);
    let h_tr = sim.hidden(&ds.train_x, &w);
    // scale H to O(1) before the solve for conditioning
    let scale = 1.0 / sim.cap();
    let mut h_tr_s = h_tr;
    h_tr_s.scale(scale);
    let t = Mat { rows: ds.train_y.len(), cols: 1, data: ds.train_y.clone() };
    let beta = match ridge_solve(&h_tr_s, &t, 1e-6) {
        Ok(b) => b,
        Err(_) => return f64::MAX,
    };
    let mut h_te = sim.hidden(&ds.test_x, &w);
    h_te.scale(scale);
    let pred = h_te.matmul(&beta);
    stats::rmse(&pred.col(0), &ds.test_y)
}

/// Mean regression error over `trials` independent dies.
pub fn mean_error(sim: &FastSim, l: usize, n_train: usize, trials: usize, seed: u64) -> f64 {
    let errs: Vec<f64> = (0..trials)
        .map(|t| regression_error(sim, l, n_train, seed + 997 * t as u64))
        .collect();
    stats::mean(&errs)
}

/// Find L_min: smallest L in `l_grid` (ascending) whose mean error is at
/// or below `threshold` (the paper uses 0.08). Returns `None` when even
/// the largest L misses the target — plotted as saturation in Fig. 7(a).
pub fn l_min(
    sim: &FastSim,
    l_grid: &[usize],
    threshold: f64,
    n_train: usize,
    trials: usize,
    seed: u64,
) -> Option<usize> {
    for &l in l_grid {
        if mean_error(sim, l, n_train, trials, seed) <= threshold {
            return Some(l);
        }
    }
    None
}

/// The standard L grid used by the Fig. 7(a) bench.
pub fn default_l_grid() -> Vec<usize> {
    vec![5, 8, 12, 16, 24, 32, 48, 64, 96, 128, 192, 256]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_decreases_with_l() {
        let sim = FastSim::default();
        let e_small = mean_error(&sim, 6, 400, 2, 1);
        let e_big = mean_error(&sim, 96, 400, 2, 1);
        assert!(e_big < e_small, "L=6: {e_small}, L=96: {e_big}");
        assert!(e_big < 0.12, "large-L error {e_big}");
    }

    #[test]
    fn lmin_finds_threshold_crossing() {
        let sim = FastSim::default();
        let grid = vec![4, 16, 64, 128];
        let lm = l_min(&sim, &grid, 0.12, 400, 2, 2);
        assert!(lm.is_some());
        assert!(lm.unwrap() >= 4 && lm.unwrap() <= 128);
    }

    #[test]
    fn degenerate_ratio_needs_more_neurons() {
        // Fig. 7(a): a far-too-small ratio (everything saturates) must be
        // worse than the 0.75 optimum at the same L.
        let good = FastSim { ratio: 0.75, ..Default::default() };
        let bad = FastSim { ratio: 0.05, ..Default::default() };
        let e_good = mean_error(&good, 48, 400, 2, 3);
        let e_bad = mean_error(&bad, 48, 400, 2, 3);
        assert!(e_bad > e_good, "good {e_good} bad {e_bad}");
    }

    #[test]
    fn tiny_sigma_hurts() {
        // sigma_VT -> 0 collapses all neurons to the same feature: only
        // ~1 effective basis function, so error stays high.
        let flat = FastSim { sigma_vt: 0.0005, ..Default::default() };
        let good = FastSim { sigma_vt: 0.020, ..Default::default() };
        let e_flat = mean_error(&flat, 64, 400, 2, 4);
        let e_good = mean_error(&good, 64, 400, 2, 4);
        assert!(e_flat > 2.0 * e_good, "flat {e_flat} good {e_good}");
    }
}
