//! Design-space exploration engine (Section III-D / IV): parallel grid
//! sweeps over operating-point parameters, the MATLAB-style fast ELM
//! simulation the paper used for Fig. 7 (linear neuron, eq. 11 counter,
//! log-normal mismatch with swept sigma_VT), and the closed-loop
//! autotuner built on top of it ([`explorer`] → [`pareto`] →
//! `ChipConfig::from_operating_point` → `Coordinator::start_tuned`;
//! DESIGN.md §10).

pub mod cache;
pub mod explorer;
pub mod lmin;
pub mod objective;
pub mod pareto;

pub use cache::EvalCache;
pub use explorer::{ExploreResult, Explorer, OperatingPoint, RegionSnapshot, SearchSpace};
pub use objective::{Evaluation, Objective};

use crate::util::mat::Mat;
use crate::util::prng::Prng;

/// Parallel map over work items using scoped std threads (no tokio in
/// the offline vendor set). Order of results matches the input order.
///
/// Each result has its own slot cell, so finishing workers never contend
/// on a whole-results lock — only the work queue is shared, and it is
/// held just long enough to pop one item.
pub fn par_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let threads = threads.max(1);
    let n = items.len();
    let slots: Vec<crate::sync::Mutex<Option<R>>> =
        (0..n).map(|_| crate::sync::Mutex::new(None)).collect();
    let work: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let queue = crate::sync::Mutex::new(work);
    std::thread::scope(|s| {
        for _ in 0..threads.min(n.max(1)) {
            s.spawn(|| loop {
                let item = queue.lock().unwrap().pop();
                match item {
                    Some((i, t)) => {
                        let r = f(t);
                        *slots[i].lock().unwrap() = Some(r);
                    }
                    None => break,
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("worker died"))
        .collect()
}

/// Default parallelism for sweeps.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// The paper's Section III-D fast simulation of the first stage:
/// linear neuron (eq. 9) + saturating counter (eq. 11), fixed
/// K_neu = 26 kHz/nA and T_neu = 56 us, mismatch the only variation.
/// The I_sat^z/I_max^z ratio is swept by scaling the input current range.
#[derive(Clone, Copy, Debug)]
pub struct FastSim {
    /// Mismatch sigma_VT [V] (swept 5..45 mV in Fig. 7a).
    pub sigma_vt: f64,
    /// I_sat^z / I_max^z ratio (Fig. 7a x-axis).
    pub ratio: f64,
    /// Counter bits b (Fig. 7c x-axis).
    pub b: u32,
    /// Conversion gain [Hz/A] (nominal 26 kHz/nA).
    pub k_neu: f64,
    /// Counting window [s] (nominal 56 us).
    pub t_neu: f64,
}

impl Default for FastSim {
    fn default() -> Self {
        FastSim {
            sigma_vt: 0.016,
            ratio: 0.75,
            b: 14,
            k_neu: 26e3 / 1e-9,
            t_neu: 56e-6,
        }
    }
}

impl FastSim {
    /// Counter cap 2^b.
    pub fn cap(&self) -> f64 {
        (1u64 << self.b) as f64
    }

    /// The saturation column current implied by (K_neu, T_neu, cap).
    pub fn i_sat_z(&self) -> f64 {
        self.cap() / (self.k_neu * self.t_neu)
    }

    /// Per-channel full-scale current for the configured ratio and d.
    pub fn i_max(&self, d: usize) -> f64 {
        self.i_sat_z() / self.ratio / d as f64
    }

    /// Sample a d x L log-normal weight matrix (eq. 12) at 300 K.
    pub fn sample_weights(&self, d: usize, l: usize, rng: &mut Prng) -> Mat {
        let ut = crate::config::thermal_voltage(300.0);
        let data = (0..d * l)
            .map(|_| rng.lognormal(0.0, self.sigma_vt / ut))
            .collect();
        Mat { rows: d, cols: l, data }
    }

    /// Hidden matrix for features in [-1,1]^d: maps to [0, I_max],
    /// projects through `w`, applies eq. 11. Returns H as floats.
    pub fn hidden(&self, xs: &[Vec<f64>], w: &Mat) -> Mat {
        let d = w.rows;
        let l = w.cols;
        let i_max = self.i_max(d);
        let cap = self.cap();
        let rows: Vec<Vec<f64>> = xs
            .iter()
            .map(|x| {
                assert_eq!(x.len(), d);
                let mut z = vec![0.0f64; l];
                for (i, &xi) in x.iter().enumerate() {
                    let ii = (xi.clamp(-1.0, 1.0) + 1.0) / 2.0 * i_max;
                    if ii == 0.0 {
                        continue;
                    }
                    let row = w.row(i);
                    for (zj, &wij) in z.iter_mut().zip(row) {
                        *zj += ii * wij;
                    }
                }
                z.iter()
                    .map(|&zj| (self.k_neu * zj * self.t_neu).floor().clamp(0.0, cap))
                    .collect()
            })
            .collect();
        Mat::from_rows(&rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order_and_results() {
        let items: Vec<u64> = (0..100).collect();
        let out = par_map(items.clone(), 8, |x| x * x);
        for (i, &o) in out.iter().enumerate() {
            assert_eq!(o, (i as u64) * (i as u64));
        }
    }

    #[test]
    fn par_map_single_thread_matches() {
        let items: Vec<u64> = (0..20).collect();
        let a = par_map(items.clone(), 1, |x| x + 1);
        let b = par_map(items, 7, |x| x + 1);
        assert_eq!(a, b);
    }

    #[test]
    fn fastsim_saturates_at_ratio() {
        // an input at exactly the saturation ratio pins the counter
        let sim = FastSim::default();
        let d = 4;
        let w = Mat::from_fn(d, 3, |_, _| 1.0); // no mismatch
        // features all at +1 => z = d * i_max = i_sat/ratio => above i_sat
        let h = sim.hidden(&[vec![1.0; d]], &w);
        assert!(h.data.iter().all(|&v| v == sim.cap()));
        // tiny inputs stay linear
        let h2 = sim.hidden(&[vec![-0.9; d]], &w);
        assert!(h2.data.iter().all(|&v| v < sim.cap()));
    }

    #[test]
    fn fastsim_weights_spread_scales_with_sigma() {
        let mut rng = Prng::new(1);
        let narrow = FastSim { sigma_vt: 0.005, ..Default::default() }
            .sample_weights(32, 32, &mut rng);
        let mut rng = Prng::new(1);
        let wide = FastSim { sigma_vt: 0.045, ..Default::default() }
            .sample_weights(32, 32, &mut rng);
        let s = |m: &Mat| {
            crate::util::stats::std(&m.data.iter().map(|x| x.ln()).collect::<Vec<_>>())
        };
        assert!(s(&wide) > 5.0 * s(&narrow));
    }

    #[test]
    fn fastsim_isat_matches_paper_numbers() {
        // K_neu = 26 kHz/nA, T_neu = 56 us, b = 14 -> I_sat^z ~ 11.25 nA
        let sim = FastSim::default();
        let isat = sim.i_sat_z();
        assert!((isat - 16384.0 / (26e3 / 1e-9 * 56e-6)).abs() / isat < 1e-12);
    }
}
