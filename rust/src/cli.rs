//! Tiny command-line parser (no clap offline): subcommand + `--key value`
//! options + `--flag` booleans, with typed getters and error reporting.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First positional token (the subcommand), if any.
    pub command: Option<String>,
    /// Remaining positionals.
    pub positional: Vec<String>,
    /// Every occurrence of each `--key value` pair, in order. Scalar
    /// getters take the last occurrence; `get_all` returns them all —
    /// for repeatable options like `velm serve --tenant a=x --tenant
    /// b=y`.
    pub options: BTreeMap<String, Vec<String>>,
    /// Bare `--flag` tokens.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse a token stream (usually `std::env::args().skip(1)`).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.options.entry(k.to_string()).or_default().push(v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    args.options.entry(name.to_string()).or_default().push(it.next().unwrap());
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options
            .get(name)
            .and_then(|v| v.last())
            .map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Every value a repeatable option was given, in order (empty when
    /// absent) — e.g. `--tenant a=x --tenant b=y`.
    pub fn get_all(&self, name: &str) -> Vec<String> {
        self.options.get(name).cloned().unwrap_or_default()
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{name}: {e}")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{name}: {e}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{name}: {e}")),
        }
    }

    /// Millisecond option returned as an optional `Duration`: absent
    /// keeps `default`, `0` means "disabled" and maps to `None` — e.g.
    /// `velm serve --read-timeout-ms 0`.
    pub fn get_ms_opt(
        &self,
        name: &str,
        default: Option<std::time::Duration>,
    ) -> Result<Option<std::time::Duration>, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => {
                let ms: u64 = v.parse().map_err(|e| format!("--{name}: {e}"))?;
                Ok((ms > 0).then_some(std::time::Duration::from_millis(ms)))
            }
        }
    }

    /// Comma-separated list option of any parseable type. `None` when
    /// the option is absent; parse errors name the option and token.
    pub fn get_list<T: std::str::FromStr>(&self, name: &str) -> Result<Option<Vec<T>>, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse::<T>()
                        .map_err(|e| format!("--{name}: '{t}': {e}"))
                })
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
        }
    }

    /// `get_list::<usize>`, e.g. `--l 32,64,128`.
    pub fn get_usize_list(&self, name: &str) -> Result<Option<Vec<usize>>, String> {
        self.get_list(name)
    }

    /// `get_list::<f64>`, e.g. `--weights 1,0.5,0.2,0`.
    pub fn get_f64_list(&self, name: &str) -> Result<Option<Vec<f64>>, String> {
        self.get_list(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        // note: a bare token right after `--x` is taken as x's value, so
        // positionals go before flag-style options
        let a = Args::parse(toks("serve extra --port 8080 --verbose")).unwrap();
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.get("port"), Some("8080"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(toks("x --k=v --n=3")).unwrap();
        assert_eq!(a.get("k"), Some("v"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 3);
    }

    #[test]
    fn typed_getters_and_defaults() {
        let a = Args::parse(toks("cmd --x 2.5")).unwrap();
        assert_eq!(a.get_f64("x", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_f64("missing", 7.0).unwrap(), 7.0);
        assert!(Args::parse(toks("cmd --x abc")).unwrap().get_f64("x", 0.0).is_err());
    }

    #[test]
    fn list_getters_parse_and_report_errors() {
        let a = Args::parse(toks("tune --l 32,64,128 --weights 1,0.5,0")).unwrap();
        assert_eq!(a.get_usize_list("l").unwrap(), Some(vec![32, 64, 128]));
        assert_eq!(a.get_f64_list("weights").unwrap(), Some(vec![1.0, 0.5, 0.0]));
        assert_eq!(a.get_list::<u32>("l").unwrap(), Some(vec![32u32, 64, 128]));
        assert_eq!(a.get_usize_list("missing").unwrap(), None);
        let bad = Args::parse(toks("tune --l 32,abc")).unwrap();
        let err = bad.get_usize_list("l").unwrap_err();
        assert!(err.contains("--l") && err.contains("abc"));
    }

    #[test]
    fn repeated_options_keep_every_occurrence() {
        let a = Args::parse(toks("serve --tenant a=digits --tenant b=brightness --chips 2"))
            .unwrap();
        // last-wins for the scalar getter, all occurrences via get_all
        assert_eq!(a.get("tenant"), Some("b=brightness"));
        assert_eq!(
            a.get_all("tenant"),
            vec!["a=digits".to_string(), "b=brightness".to_string()]
        );
        assert_eq!(a.get_all("chips"), vec!["2".to_string()]);
        assert!(a.get_all("missing").is_empty());
        // equals form contributes too
        let b = Args::parse(toks("x --t=1 --t 2")).unwrap();
        assert_eq!(b.get_all("t"), vec!["1".to_string(), "2".to_string()]);
    }

    #[test]
    fn trailing_flag_not_eating_next_flag() {
        let a = Args::parse(toks("cmd --a --b 5")).unwrap();
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("5"));
    }

    #[test]
    fn ms_option_maps_zero_to_disabled() {
        let dflt = Some(std::time::Duration::from_secs(2));
        let a = Args::parse(toks("serve --read-timeout-ms 250")).unwrap();
        assert_eq!(
            a.get_ms_opt("read-timeout-ms", dflt).unwrap(),
            Some(std::time::Duration::from_millis(250))
        );
        assert_eq!(a.get_ms_opt("missing", dflt).unwrap(), dflt);
        let z = Args::parse(toks("serve --read-timeout-ms 0")).unwrap();
        assert_eq!(z.get_ms_opt("read-timeout-ms", dflt).unwrap(), None);
        let bad = Args::parse(toks("serve --read-timeout-ms abc")).unwrap();
        assert!(bad.get_ms_opt("read-timeout-ms", dflt).is_err());
    }
}
