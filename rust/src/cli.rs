//! Tiny command-line parser (no clap offline): subcommand + `--key value`
//! options + `--flag` booleans, with typed getters and error reporting.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First positional token (the subcommand), if any.
    pub command: Option<String>,
    /// Remaining positionals.
    pub positional: Vec<String>,
    /// `--key value` pairs.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` tokens.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse a token stream (usually `std::env::args().skip(1)`).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    args.options.insert(name.to_string(), it.next().unwrap());
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{name}: {e}")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{name}: {e}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{name}: {e}")),
        }
    }

    /// Comma-separated list option of any parseable type. `None` when
    /// the option is absent; parse errors name the option and token.
    pub fn get_list<T: std::str::FromStr>(&self, name: &str) -> Result<Option<Vec<T>>, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse::<T>()
                        .map_err(|e| format!("--{name}: '{t}': {e}"))
                })
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
        }
    }

    /// `get_list::<usize>`, e.g. `--l 32,64,128`.
    pub fn get_usize_list(&self, name: &str) -> Result<Option<Vec<usize>>, String> {
        self.get_list(name)
    }

    /// `get_list::<f64>`, e.g. `--weights 1,0.5,0.2,0`.
    pub fn get_f64_list(&self, name: &str) -> Result<Option<Vec<f64>>, String> {
        self.get_list(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        // note: a bare token right after `--x` is taken as x's value, so
        // positionals go before flag-style options
        let a = Args::parse(toks("serve extra --port 8080 --verbose")).unwrap();
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.get("port"), Some("8080"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(toks("x --k=v --n=3")).unwrap();
        assert_eq!(a.get("k"), Some("v"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 3);
    }

    #[test]
    fn typed_getters_and_defaults() {
        let a = Args::parse(toks("cmd --x 2.5")).unwrap();
        assert_eq!(a.get_f64("x", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_f64("missing", 7.0).unwrap(), 7.0);
        assert!(Args::parse(toks("cmd --x abc")).unwrap().get_f64("x", 0.0).is_err());
    }

    #[test]
    fn list_getters_parse_and_report_errors() {
        let a = Args::parse(toks("tune --l 32,64,128 --weights 1,0.5,0")).unwrap();
        assert_eq!(a.get_usize_list("l").unwrap(), Some(vec![32, 64, 128]));
        assert_eq!(a.get_f64_list("weights").unwrap(), Some(vec![1.0, 0.5, 0.0]));
        assert_eq!(a.get_list::<u32>("l").unwrap(), Some(vec![32u32, 64, 128]));
        assert_eq!(a.get_usize_list("missing").unwrap(), None);
        let bad = Args::parse(toks("tune --l 32,abc")).unwrap();
        let err = bad.get_usize_list("l").unwrap_err();
        assert!(err.contains("--l") && err.contains("abc"));
    }

    #[test]
    fn trailing_flag_not_eating_next_flag() {
        let a = Args::parse(toks("cmd --a --b 5")).unwrap();
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("5"));
    }
}
